//! The fused AR-A2A communication algorithms (§III-D, Algorithms 1–2).
//!
//! Both schedules exploit the bandwidth hierarchy by overlapping
//! intra-node collective rounds with inter-node pairwise transfers:
//!
//! * **Fused RS-Combine** (Alg. 1, Fig. 9a) — MoE output path.  Per
//!   pairwise round the node reduce-scatters one destination block inside
//!   the TP group while the NIC ships the previous (already-reduced)
//!   block to its destination node; a final intra-node AG reassembles the
//!   full hidden dimension.  n rounds intra + (n−1) rounds inter,
//!   overlapped ⇒ O(n) time, O(t·h·m) staging space.
//!
//! * **Fused AG-Dispatch** (Alg. 2, Fig. 9b) — MoE input path.  The
//!   hidden states are already replicated in the MoE TP group, so each TP
//!   rank ships only its 1/m hidden slice of the token rows routed to
//!   each remote node; receivers all-gather the slices.  The AG of round
//!   i−1 overlaps the pairwise send of round i.  (n−1) rounds intra +
//!   (n−1) inter, O(n) time, O(1) extra space.
//!
//! Implementations move real `f32` data (verified against the unfused
//! primitives and a dense reference) *and* emit their round structure as
//! the shared schedule IR (`timing::schedule`), played under any
//! [`CommCost`] — so the same code answers "is it correct?", "what does
//! the overlap buy?" (Fig. 12), and "what does contention change?".

use super::primitives::combine_reference;
use super::world::{RankWorld, Tensor2};
use crate::gantt::Trace;
use crate::pipeline::chunked_pipeline;
use crate::timing::schedule::{
    backend_combine_ir, backend_dispatch_ir, rs_combine_ir, EpShape, Schedule, Step,
};
use crate::timing::{CommCost, CommDomain, DispatchBackend};

/// Result of a fused collective: per-node output tensors plus the timed
/// trace (async schedule) and the equivalent synchronous makespan.
#[derive(Debug, Clone)]
pub struct FusedResult {
    /// combined output per node (replicated across its TP ranks)
    pub per_node: Vec<Tensor2>,
    /// overlapped (async) schedule
    pub trace: Trace,
    /// makespan of the same rounds run back-to-back (sync ablation)
    pub sync_time: f64,
    /// makespan with chunked micro-batch pipelining of the expert
    /// compute against the collective; equals the async makespan for
    /// the unchunked single-shot collectives (K = 1, no compute)
    pub pipelined_time: f64,
}

impl FusedResult {
    pub fn async_time(&self) -> f64 {
        self.trace.makespan()
    }

    pub fn speedup(&self) -> f64 {
        self.sync_time / self.async_time().max(1e-12)
    }
}

/// **Algorithm 1 — Fused RS-Combine Pairwise Communication.**
///
/// `contrib[node][tp]`: partial contribution held by rank (node, tp),
/// `n·t_loc × h` rows stacked by destination node.  Ranks of one node sum
/// to that node's true contribution (TP row-parallel state).
///
/// Output per node: `t_loc × h` fully combined hidden states for its own
/// tokens (`Y[dst] = Σ_src Σ_tp contrib[src][tp][dst]`).
pub fn fused_rs_combine<C: CommCost>(
    world: &RankWorld,
    contrib: &[Vec<Tensor2>],
    cost: &C,
) -> FusedResult {
    fused_rs_combine_on(world, contrib, cost, DispatchBackend::AllToAll)
}

/// [`fused_rs_combine`] with the *time plane* shaped by `backend`.  The
/// data plane is backend-invariant — every algorithm delivers the same
/// combined tensors, verified against the unfused reference — so only
/// the schedule (launch rounds, wire volume, collective shape) changes.
/// `DispatchBackend::AllToAll` reproduces [`fused_rs_combine`]'s
/// Algorithm 1 rounds bit-for-bit.
pub fn fused_rs_combine_on<C: CommCost>(
    world: &RankWorld,
    contrib: &[Vec<Tensor2>],
    cost: &C,
    backend: DispatchBackend,
) -> FusedResult {
    let (n, m) = (world.n_nodes, world.m_per_node);
    let h = contrib[0][0].cols;
    let t_total = contrib[0][0].rows;
    assert!(t_total % n == 0, "rows must stack n destination blocks");
    let t_loc = t_total / n;
    assert!(h % m == 0, "hidden must divide TP degree");

    // --- data plane -----------------------------------------------------
    // §Perf: accumulate directly from each node's TP-summed contribution
    // into the destination's output rows — no per-(src, dst, tp) staging
    // tensors.  The RS (intra sum), the pairwise shipment and the final
    // AG all collapse into strided row adds; the *schedule* (time plane
    // below) still models the real rounds.  Semantics are unchanged and
    // property-tested against the unfused pipeline.
    let mut per_node: Vec<Tensor2> = (0..n).map(|_| Tensor2::zeros(t_loc, h)).collect();
    let mut sum = Tensor2::zeros(t_total, h);
    for node_bufs in contrib.iter().take(n) {
        // intra-node RS: sum the m TP-partial copies (reused buffer)
        sum.data.copy_from_slice(&node_bufs[0].data);
        for b in &node_bufs[1..] {
            sum.add_assign(b);
        }
        // pairwise rounds + AG: node src's dst-block adds into dst's rows
        for (dst, out) in per_node.iter_mut().enumerate() {
            let blk = &sum.data[dst * t_loc * h..(dst + 1) * t_loc * h];
            for (a, b) in out.data.iter_mut().zip(blk) {
                *a += *b;
            }
        }
    }

    // --- time plane -------------------------------------------------------
    // Alg. 1's round structure as the shared IR: per node, n RS rounds
    // (one per destination block) on the intra lane; n-1 sends on the
    // inter lane, send_i gated on RS_i; final AG gated on the last send
    // (full-duplex pairwise: receives land at the senders' send end).
    let blk_bytes = (t_loc * h * 4) as f64;
    let shape = EpShape {
        nodes: n,
        rounds: n,
        tp: m,
        tp_domain: CommDomain::IntraNode,
        ep_domain: CommDomain::InterNode,
    };
    let sched = backend_combine_ir(backend, &shape, blk_bytes, blk_bytes);
    let trace = sched.play(cost).trace;
    let sync_time = sched.sync_time(cost);
    let pipelined_time = trace.makespan();

    FusedResult { per_node, trace, sync_time, pipelined_time }
}

/// [`fused_rs_combine`] with the expert GroupGEMM that *produces* the
/// contributions pipelined against the combine in `chunks` micro-batch
/// chunks (EPS-MoE): chunk i's combine rounds ride the comm lanes while
/// chunk i+1's GEMM runs on the node's compute stream.  The data plane
/// really runs chunk-by-chunk — each chunk accumulates its own row
/// slice of every destination block — and is verified bit-identical to
/// the unchunked path in tests.  `pipelined_time` carries the
/// overlapped makespan and `trace` the chunked Gantt (Fig. 12's
/// pipeline view); `sync_time` stays the comm-only back-to-back
/// ablation of the chunked rounds (comparable with the other
/// constructors — compute is never part of that field).
pub fn fused_rs_combine_chunked<C: CommCost>(
    world: &RankWorld,
    contrib: &[Vec<Tensor2>],
    cost: &C,
    chunks: usize,
    gemm_flops: f64,
) -> FusedResult {
    let (n, m) = (world.n_nodes, world.m_per_node);
    let h = contrib[0][0].cols;
    let t_total = contrib[0][0].rows;
    assert!(t_total % n == 0, "rows must stack n destination blocks");
    let t_loc = t_total / n;
    let k = chunks.max(1);

    // --- data plane: per source node, TP-sum once, then ship each
    // destination block one micro-batch row-slice at a time
    let mut per_node: Vec<Tensor2> = (0..n).map(|_| Tensor2::zeros(t_loc, h)).collect();
    let mut sum = Tensor2::zeros(t_total, h);
    for node_bufs in contrib.iter().take(n) {
        sum.data.copy_from_slice(&node_bufs[0].data);
        for b in &node_bufs[1..] {
            sum.add_assign(b);
        }
        for (dst, out) in per_node.iter_mut().enumerate() {
            for ci in 0..k {
                let (lo, hi) = (ci * t_loc / k, (ci + 1) * t_loc / k);
                let blk = &sum.data[(dst * t_loc + lo) * h..(dst * t_loc + hi) * h];
                for (a, b) in out.data[lo * h..hi * h].iter_mut().zip(blk) {
                    *a += *b;
                }
            }
        }
    }

    // --- time plane: the K-chunk pipeline schedule
    let kf = k as f64;
    let blk_bytes = (t_loc * h * 4) as f64 / kf;
    let comb_ir = || rs_combine_ir(n, n, m, blk_bytes, blk_bytes, CommDomain::IntraNode);
    let sched = chunked_pipeline(
        k,
        n,
        |_| Schedule::default(), // no dispatch stage: GEMM feeds combine
        |c, node| Step::compute(node, 0, format!("G{c}"), gemm_flops / kf, vec![]),
        |_| comb_ir(),
    );
    let played = sched.play(cost);
    let pipelined_time = played.makespan();
    FusedResult {
        per_node,
        trace: played.trace,
        sync_time: kf * comb_ir().sync_time(cost),
        pipelined_time,
    }
}

/// Routing plan for dispatch: `route[src][tok]` = destination node of each
/// of node src's `t_loc` tokens (top-k flattened upstream: a token routed
/// to k experts appears k times with its gate context handled by combine).
pub type Route = Vec<Vec<usize>>;

/// **Algorithm 2 — Fused AG-Dispatch Pairwise Communication.**
///
/// `tokens[src]`: `t_loc × h` hidden states of node src (replicated in its
/// TP group); `route[src][t]` destination node per token.
///
/// Output per node `d`: rows of every token routed to `d`, ordered by
/// (source node, token index), with full hidden dimension — i.e. exactly
/// what the unfused AG-then-dispatch produces.
pub fn fused_ag_dispatch<C: CommCost>(
    world: &RankWorld,
    tokens: &[Tensor2],
    route: &Route,
    cost: &C,
) -> FusedResult {
    fused_ag_dispatch_on(world, tokens, route, cost, DispatchBackend::AllToAll)
}

/// [`fused_ag_dispatch`] with the *time plane* shaped by `backend` —
/// the dispatch mirror of [`fused_rs_combine_on`]: same delivered
/// tensors, backend-shaped schedule.
pub fn fused_ag_dispatch_on<C: CommCost>(
    world: &RankWorld,
    tokens: &[Tensor2],
    route: &Route,
    cost: &C,
    backend: DispatchBackend,
) -> FusedResult {
    let (n, m) = (world.n_nodes, world.m_per_node);
    let h = tokens[0].cols;
    assert!(h % m == 0);
    let w = h / m;

    // --- data plane -----------------------------------------------------
    // Node src, TP rank p ships slice p of the rows destined to node dst.
    // Receiver all-gathers the m slices -> full rows.
    let mut per_node: Vec<Tensor2> = Vec::with_capacity(n);
    let mut max_rows_sent = vec![0usize; n]; // per src, largest remote block
    for dst in 0..n {
        // gather (src, tok) pairs routed to dst, source-major order
        let mut rows: Vec<(usize, usize)> = Vec::new();
        for (src, r) in route.iter().enumerate() {
            for (tok, &d) in r.iter().enumerate() {
                if d == dst {
                    rows.push((src, tok));
                }
            }
        }
        let mut out = Tensor2::zeros(rows.len(), h);
        for (o, (src, tok)) in rows.iter().enumerate() {
            // simulate slice-wise arrival + AG: copy each TP slice
            for p in 0..m {
                let cols = p * w..(p + 1) * w;
                let src_row = tokens[*src].row(*tok);
                out.row_mut(o)[cols.clone()].copy_from_slice(&src_row[cols]);
            }
            if *src != dst {
                max_rows_sent[*src] += 1;
            }
        }
        per_node.push(out);
    }

    // --- time plane -------------------------------------------------------
    // Balanced-load model for the schedule: each pairwise round ships the
    // average remote block; AG of round i-1 overlaps send of round i.
    let total_remote: usize = max_rows_sent.iter().sum();
    let avg_rows = if n > 1 { total_remote as f64 / (n * (n - 1)) as f64 } else { 0.0 };
    let send_bytes = avg_rows * (w * 4) as f64 * m as f64; // all m lanes per round
    let ag_bytes = avg_rows * (h * 4) as f64;
    let shape = EpShape {
        nodes: n,
        rounds: n,
        tp: m,
        tp_domain: CommDomain::IntraNode,
        ep_domain: CommDomain::InterNode,
    };
    let sched = backend_dispatch_ir(backend, &shape, send_bytes, ag_bytes);
    let trace = sched.play(cost).trace;
    let sync_time = sched.sync_time(cost);
    let pipelined_time = trace.makespan();

    FusedResult { per_node, trace, sync_time, pipelined_time }
}

/// Unfused dispatch reference: every destination's rows with full hidden.
pub fn dispatch_reference(tokens: &[Tensor2], route: &Route) -> Vec<Tensor2> {
    let n = tokens.len();
    let h = tokens[0].cols;
    (0..n)
        .map(|dst| {
            let mut rows: Vec<Vec<f32>> = Vec::new();
            for (src, r) in route.iter().enumerate() {
                for (tok, &d) in r.iter().enumerate() {
                    if d == dst {
                        rows.push(tokens[src].row(tok).to_vec());
                    }
                }
            }
            if rows.is_empty() {
                Tensor2::zeros(0, h)
            } else {
                Tensor2::from_rows(rows)
            }
        })
        .collect()
}

/// Expose the dense combine reference at this level too.
pub fn rs_combine_reference(world: &RankWorld, contrib: &[Vec<Tensor2>]) -> Vec<Tensor2> {
    combine_reference(world, contrib)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::cost::CollectiveCost;
    use crate::comm::primitives::{synth_contrib, unfused_rs_a2a_ag};
    use crate::config::ClusterConfig;
    use crate::gantt::Lane;
    use crate::timing::NetSimCost;

    fn cost() -> CollectiveCost {
        CollectiveCost::new(&ClusterConfig::ascend910b())
    }

    #[test]
    fn alg1_matches_dense_reference() {
        let world = RankWorld::new(4, 4);
        let contrib = synth_contrib(&world, 8, 16, 7);
        let res = fused_rs_combine(&world, &contrib, &cost());
        let want = rs_combine_reference(&world, &contrib);
        for (g, w) in res.per_node.iter().zip(&want) {
            assert!(g.approx_eq(w, 1e-4), "diff {}", g.max_abs_diff(w));
        }
    }

    #[test]
    fn alg1_matches_unfused_pipeline() {
        let world = RankWorld::new(2, 4);
        let contrib = synth_contrib(&world, 4, 8, 3);
        let fused = fused_rs_combine(&world, &contrib, &cost());
        let (unfused, _) = unfused_rs_a2a_ag(&world, &contrib, &cost());
        for (g, w) in fused.per_node.iter().zip(&unfused) {
            assert!(g.approx_eq(w, 1e-4));
        }
    }

    #[test]
    fn alg1_async_beats_sync() {
        let world = RankWorld::new(4, 8);
        let contrib = synth_contrib(&world, 64, 128, 1);
        let res = fused_rs_combine(&world, &contrib, &cost());
        assert!(res.async_time() < res.sync_time, "overlap must help");
        assert!(res.trace.lanes_are_serial());
        // Fig. 12: async gain ≈ hidden intra-node time; async ≥ inter time
        let inter_busy = res.trace.busy(&Lane::Inter(0));
        assert!(res.async_time() >= inter_busy - 1e-12);
    }

    #[test]
    fn alg1_trace_has_expected_round_structure() {
        let world = RankWorld::new(3, 2);
        let contrib = synth_contrib(&world, 2, 4, 9);
        let res = fused_rs_combine(&world, &contrib, &cost());
        let n0_intra =
            res.trace.spans.iter().filter(|s| s.lane == Lane::Intra(0)).count();
        let n0_inter =
            res.trace.spans.iter().filter(|s| s.lane == Lane::Inter(0)).count();
        assert_eq!(n0_intra, 3 + 1); // n RS rounds + AG
        assert_eq!(n0_inter, 2); // n-1 pairwise sends
    }

    #[test]
    fn chunked_combine_keeps_numerics_and_overlaps_gemm() {
        let world = RankWorld::new(4, 8);
        let contrib = synth_contrib(&world, 64, 128, 1);
        let c = cost();
        let base = fused_rs_combine(&world, &contrib, &c);
        // a GEMM 4x the combine time: chunk i's combine hides fully
        // inside chunk i+1's GEMM window, so the pipeline must beat the
        // serial chain even though the small blocks are launch-dominated
        let cl = ClusterConfig::ascend910b();
        let gemm_flops = 4.0 * base.async_time() * cl.flops * cl.mfu;
        let chunked = fused_rs_combine_chunked(&world, &contrib, &c, 4, gemm_flops);
        // data plane: bit-identical outputs (chunking is associative)
        for (a, b) in chunked.per_node.iter().zip(&base.per_node) {
            assert!(a.approx_eq(b, 0.0), "chunking must not change the data");
        }
        // time plane: the pipelined makespan beats GEMM-then-combine
        let serial_chain = c.compute_time(gemm_flops) + base.async_time();
        assert!(
            chunked.pipelined_time < serial_chain,
            "pipelined {} !< serial chain {serial_chain}",
            chunked.pipelined_time
        );
        assert!(chunked.trace.lanes_are_serial());
        assert!(
            chunked.trace.spans.iter().any(|s| matches!(s.lane, Lane::Stream(_, 0))),
            "chunked trace must show the compute stream"
        );
    }

    #[test]
    fn unchunked_pipelined_time_equals_async() {
        let world = RankWorld::new(2, 4);
        let contrib = synth_contrib(&world, 4, 8, 3);
        let res = fused_rs_combine(&world, &contrib, &cost());
        assert_eq!(res.pipelined_time, res.async_time());
    }

    #[test]
    fn backend_variants_share_the_data_plane() {
        let world = RankWorld::new(4, 4);
        let contrib = synth_contrib(&world, 8, 16, 7);
        let c = cost();
        let base = fused_rs_combine(&world, &contrib, &c);
        for b in DispatchBackend::ALL {
            let res = fused_rs_combine_on(&world, &contrib, &c, b);
            for (g, w) in res.per_node.iter().zip(&base.per_node) {
                assert!(g.approx_eq(w, 0.0), "{b}: data plane must be backend-invariant");
            }
            assert!(res.async_time() > 0.0 && res.sync_time > 0.0, "{b}");
        }
        // the default-backend variant IS the plain constructor
        let a2a = fused_rs_combine_on(&world, &contrib, &c, DispatchBackend::AllToAll);
        assert_eq!(a2a.async_time(), base.async_time());
        assert_eq!(a2a.sync_time, base.sync_time);
        assert_eq!(a2a.trace.spans.len(), base.trace.spans.len());
    }

    #[test]
    fn backend_variants_reshape_the_dispatch_schedule() {
        let world = RankWorld::new(3, 2);
        let h = 8;
        let tokens: Vec<Tensor2> = (0..3)
            .map(|s| Tensor2::from_fn(5, h, |r, c| (s * 100 + r * 10 + c) as f32))
            .collect();
        let route: Route =
            vec![vec![0, 1, 2, 1, 0], vec![2, 2, 0, 1, 1], vec![0, 0, 0, 2, 1]];
        let c = cost();
        let want = dispatch_reference(&tokens, &route);
        let a2a = fused_ag_dispatch_on(&world, &tokens, &route, &c, DispatchBackend::AllToAll);
        let ll =
            fused_ag_dispatch_on(&world, &tokens, &route, &c, DispatchBackend::FusedLowLatency);
        let agm =
            fused_ag_dispatch_on(&world, &tokens, &route, &c, DispatchBackend::AllGatherMask);
        for res in [&a2a, &ll, &agm] {
            for (g, w) in res.per_node.iter().zip(&want) {
                assert!(g.approx_eq(w, 0.0), "dispatch must stay exact");
            }
        }
        // tiny payloads are α-bound: the single-launch kernels beat the
        // pairwise rounds, and the schedules really are different shapes
        assert!(ll.async_time() < a2a.async_time());
        assert!(agm.async_time() < a2a.async_time());
        assert!(ll.trace.spans.len() < a2a.trace.spans.len());
    }

    #[test]
    fn alg2_matches_dispatch_reference() {
        let world = RankWorld::new(3, 2);
        let h = 8;
        let tokens: Vec<Tensor2> = (0..3)
            .map(|s| Tensor2::from_fn(5, h, |r, c| (s * 100 + r * 10 + c) as f32))
            .collect();
        let route: Route =
            vec![vec![0, 1, 2, 1, 0], vec![2, 2, 0, 1, 1], vec![0, 0, 0, 2, 1]];
        let res = fused_ag_dispatch(&world, &tokens, &route, &cost());
        let want = dispatch_reference(&tokens, &route);
        for (g, w) in res.per_node.iter().zip(&want) {
            assert!(g.approx_eq(w, 0.0), "dispatch must be exact");
        }
    }

    #[test]
    fn alg2_async_beats_sync() {
        let world = RankWorld::new(4, 4);
        let h = 64;
        let tokens: Vec<Tensor2> =
            (0..4).map(|s| Tensor2::from_fn(32, h, |r, c| (s + r + c) as f32)).collect();
        let route: Route =
            (0..4).map(|s| (0..32).map(|t| (s + t) % 4).collect()).collect();
        let res = fused_ag_dispatch(&world, &tokens, &route, &cost());
        assert!(res.async_time() < res.sync_time);
        assert!(res.trace.lanes_are_serial());
    }

    #[test]
    fn alg2_space_is_o1_alg1_space_is_om() {
        // Structural assertion from §III-D: Alg. 1 stages one t_loc×h block
        // per TP rank (space ∝ m); Alg. 2 forwards slices in place.  We
        // check the *data* invariant that underlies it: Alg. 1's staging
        // (reduced) holds n·m slices per node vs Alg. 2's zero staging.
        // (Compile-time design note — runtime behaviour covered above.)
        let world = RankWorld::new(2, 2);
        assert_eq!(world.size(), 4);
    }

    #[test]
    fn single_node_degenerates_to_local() {
        let world = RankWorld::new(1, 4);
        let contrib = synth_contrib(&world, 4, 8, 5);
        let res = fused_rs_combine(&world, &contrib, &cost());
        let want = rs_combine_reference(&world, &contrib);
        assert!(res.per_node[0].approx_eq(&want[0], 1e-4));
        assert_eq!(
            res.trace.spans.iter().filter(|s| matches!(s.lane, Lane::Inter(_))).count(),
            0
        );
    }

    #[test]
    fn schedule_ir_is_cost_backend_agnostic() {
        // the IR carries the round structure, not durations: Alg. 1's
        // intra collectives and per-node sends time identically under
        // both backends (no lane is shared)...
        let world = RankWorld::new(4, 8);
        let contrib = synth_contrib(&world, 64, 128, 2);
        let netsim = NetSimCost::new(&ClusterConfig::ascend910b());
        let analytic = fused_rs_combine(&world, &contrib, &cost());
        let contended = fused_rs_combine(&world, &contrib, &netsim);
        assert!((contended.async_time() - analytic.async_time()).abs() < 1e-15);
        assert_eq!(contended.trace.spans.len(), analytic.trace.spans.len());
        // ...while the SAME builder with an oversized (inter-node) TP
        // group strictly stretches under the contention-aware backend
        use crate::timing::schedule::rs_combine_ir;
        let oversized = rs_combine_ir(1, 4, 16, 2e6, 2e6, CommDomain::InterNode);
        let (a, _) = oversized.makespans(&cost());
        let (n, _) = oversized.makespans(&netsim);
        assert!(n > a, "shared-NIC RS/AG must stretch: {n} !> {a}");
    }
}
