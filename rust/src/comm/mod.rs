//! Collective communication: analytic cost models (Table I, Eqs. 1–3),
//! data-level primitives over simulated ranks, and the paper's fused
//! AR-A2A schedules (Algorithms 1–2).

pub mod cost;
pub mod fused;
pub mod primitives;
pub mod ring;
pub mod world;

pub use cost::{CollectiveCost, CommDomain};
pub use world::{RankId, RankWorld, Tensor2};
