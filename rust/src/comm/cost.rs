//! Analytic collective cost model — §III-B2, Table I, Eqs. (1)–(3).
//!
//! The paper models each collective with a per-round volume, a round
//! count, and a communication domain (intra- vs inter-node); we realize
//! that with an α–β (latency–bandwidth) link model:
//!
//!   time(bytes) = α + bytes / β                  (one full-duplex round)
//!
//!   RS(size, d) = AG(size, d):  1 round of size/d          (Broadcast alg.)
//!   AR(size, d) = RS + AG                                  (Eq. 2)
//!   A2A(size, d): d−1 rounds of size/d each                (Pairwise alg.)
//!   P2P(size):    1 round of size
//!
//! `size` is the *bytes of the full tensor being synchronized* on one
//! rank; degrees ≤ gpus_per_node stay intra-node (Fig. 3's d ≤ 8 regime).
//!
//! The collectives themselves (and everything above them) live in the
//! [`CommCost`] trait — this type supplies only the α–β primitive and is
//! the trait's *optimistic* implementation: it ignores lane sharing (the
//! contention-aware counterpart is [`crate::timing::NetSimCost`]).

use crate::config::ClusterConfig;
use crate::timing::CommCost;
pub use crate::timing::CommDomain;

/// Analytic (contention-free) cost model bound to one cluster.
#[derive(Debug, Clone)]
pub struct CollectiveCost {
    pub cluster: ClusterConfig,
}

impl CollectiveCost {
    pub fn new(cluster: &ClusterConfig) -> Self {
        Self { cluster: cluster.clone() }
    }
}

impl CommCost for CollectiveCost {
    fn cluster(&self) -> &ClusterConfig {
        &self.cluster
    }

    fn round_shared(&self, bytes: f64, _sharers: usize, domain: CommDomain) -> f64 {
        if bytes <= 0.0 {
            return 0.0;
        }
        let (alpha, beta) = match domain {
            CommDomain::IntraNode => (self.cluster.intra_lat, self.cluster.intra_bw),
            CommDomain::InterNode => (self.cluster.inter_lat, self.cluster.inter_bw),
        };
        alpha + bytes / beta
    }

    fn rebind(&self, cluster: &ClusterConfig) -> Self {
        Self::new(cluster)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cc() -> CollectiveCost {
        CollectiveCost::new(&ClusterConfig::ascend910b())
    }

    #[test]
    fn degree_one_is_free() {
        let c = cc();
        assert_eq!(c.all_reduce(1e6, 1, CommDomain::IntraNode), 0.0);
        assert_eq!(c.all_to_all(1e6, 1, CommDomain::InterNode), 0.0);
    }

    #[test]
    fn ar_equals_rs_plus_ag() {
        let c = cc();
        let (b, d) = (8e6, 8);
        let ar = c.all_reduce(b, d, CommDomain::IntraNode);
        let rs = c.reduce_scatter(b, d, CommDomain::IntraNode);
        let ag = c.all_gather(b, d, CommDomain::IntraNode);
        assert!((ar - (rs + ag)).abs() < 1e-12);
    }

    #[test]
    fn a2a_rounds_scale_with_degree() {
        // Table I: Pairwise needs d-1 rounds of size/d; with size fixed the
        // volume term is ~constant but the α term grows linearly.
        let c = cc();
        let t4 = c.all_to_all(4e6, 4, CommDomain::InterNode);
        let t16 = c.all_to_all(4e6, 16, CommDomain::InterNode);
        assert!(t16 > t4 * 0.9);
    }

    #[test]
    fn inter_node_slower_than_intra() {
        let c = cc();
        assert!(
            c.all_reduce(64e6, 8, CommDomain::InterNode)
                > c.all_reduce(64e6, 8, CommDomain::IntraNode)
        );
    }

    #[test]
    fn fig3_shape_tp_worse_than_ep_at_32() {
        // Fig. 3 (left): at d=32 the AR-based TP overtakes A2A-based EP.
        let c = cc();
        let m = crate::config::MoEModelConfig::deepseek_r1();
        let bytes = (16 * 1024 * m.hidden * m.dtype_bytes) as f64; // b*s*h
        let ar = c.ar_auto(bytes, 32);
        let a2a = c.a2a_auto(bytes * m.top_k as f64 / 32.0, 32);
        assert!(ar > a2a, "AR {ar:.6} should exceed A2A {a2a:.6} at d=32");
    }

    #[test]
    fn monotone_in_bytes() {
        let c = cc();
        let mut prev = 0.0;
        for kb in [1, 16, 256, 4096, 65536] {
            let t = c.all_reduce((kb * 1024) as f64, 8, CommDomain::InterNode);
            assert!(t > prev);
            prev = t;
        }
    }

    #[test]
    fn ignores_lane_sharing() {
        // the analytic model is the optimistic per-link view
        let c = cc();
        let a = c.round_shared(1e6, 1, CommDomain::InterNode);
        let b = c.round_shared(1e6, 8, CommDomain::InterNode);
        assert_eq!(a, b);
    }
}
