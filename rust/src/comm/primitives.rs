//! Data-level collective primitives over simulated ranks.
//!
//! These move real bytes between per-rank buffers (correctness) and
//! report the α–β time the same movement would take on the modeled
//! cluster (performance).  The *unfused* baselines (vLLM/Tutel-style
//! synchronous RS → A2A → AG) are built from these; the fused schedules
//! in [`super::fused`] are verified against them.

use super::world::{RankWorld, Tensor2};
use crate::timing::{CommCost, CommDomain};

/// All-Reduce (sum) across a group of rank buffers; every buffer ends up
/// holding the elementwise sum.  Returns modeled time (Eq. 2).
pub fn all_reduce(bufs: &mut [Tensor2], cost: &impl CommCost, domain: CommDomain) -> f64 {
    let d = bufs.len();
    if d <= 1 {
        return 0.0;
    }
    let mut sum = bufs[0].clone();
    for b in &bufs[1..] {
        sum.add_assign(b);
    }
    let bytes = sum.bytes();
    for b in bufs.iter_mut() {
        *b = sum.clone();
    }
    cost.all_reduce(bytes, d, domain)
}

/// Reduce-Scatter (sum) along columns: rank `i` keeps column slice `i` of
/// the sum.  Returns (per-rank slices, modeled time).
pub fn reduce_scatter_cols(
    bufs: &[Tensor2],
    cost: &impl CommCost,
    domain: CommDomain,
) -> (Vec<Tensor2>, f64) {
    let d = bufs.len();
    assert!(d >= 1);
    let (rows, cols) = (bufs[0].rows, bufs[0].cols);
    assert!(cols % d == 0, "cols {cols} not divisible by group {d}");
    let mut sum = bufs[0].clone();
    for b in &bufs[1..] {
        sum.add_assign(b);
    }
    let w = cols / d;
    let slices = (0..d).map(|i| sum.slice_cols(i * w..(i + 1) * w)).collect();
    let t = cost.reduce_scatter((rows * cols * 4) as f64, d, domain);
    (slices, t)
}

/// All-Gather along columns: every rank gets the concatenation of all
/// ranks' column slices.  Returns (full tensor, modeled time).
pub fn all_gather_cols(
    slices: &[Tensor2],
    cost: &impl CommCost,
    domain: CommDomain,
) -> (Tensor2, f64) {
    let d = slices.len();
    assert!(d >= 1);
    let rows = slices[0].rows;
    let w = slices[0].cols;
    let mut full = Tensor2::zeros(rows, w * d);
    for (i, s) in slices.iter().enumerate() {
        assert_eq!((s.rows, s.cols), (rows, w));
        full.set_cols(i * w, s);
    }
    let t = cost.all_gather((rows * w * d * 4) as f64, d, domain);
    (full, t)
}

/// All-To-All over row blocks: participant `i` sends its `j`-th row block
/// to participant `j`.  `send[i][j]` -> `recv[j][i]`.  Returns
/// (received blocks per rank, modeled time with the Pairwise algorithm).
pub fn all_to_all_rows(
    send: &[Vec<Tensor2>],
    cost: &impl CommCost,
    domain: CommDomain,
) -> (Vec<Vec<Tensor2>>, f64) {
    let d = send.len();
    assert!(send.iter().all(|s| s.len() == d));
    let mut recv: Vec<Vec<Tensor2>> = vec![Vec::with_capacity(d); d];
    for j in 0..d {
        for (_i, si) in send.iter().enumerate() {
            recv[j].push(si[j].clone());
        }
    }
    // Pairwise: d-1 rounds; per round each rank ships one block.
    let per_round: f64 = send
        .iter()
        .flat_map(|s| s.iter())
        .map(|t| t.bytes())
        .sum::<f64>()
        / (d * d) as f64;
    let t = if d > 1 {
        (d as f64 - 1.0) * cost.round(per_round, domain)
    } else {
        0.0
    };
    (recv, t)
}

/// The **unfused** hybrid TP-EP output path (what MixServe's sync ablation
/// and the Tutel baseline run): intra-node RS, inter-node A2A of the
/// scattered slices, intra-node AG.  Eq. (13) without overlap.
///
/// `contrib[node][tp]` = partial contribution tensor held by rank
/// (node, tp), laid out as `n_nodes` stacked row blocks (one per
/// destination node), each `t_loc × h`.
/// Returns (per-node combined `t_loc × h` output, total modeled time).
pub fn unfused_rs_a2a_ag(
    world: &RankWorld,
    contrib: &[Vec<Tensor2>],
    cost: &impl CommCost,
) -> (Vec<Tensor2>, f64) {
    let (n, m) = (world.n_nodes, world.m_per_node);
    let h = contrib[0][0].cols;
    let t_total = contrib[0][0].rows;
    assert!(t_total % n == 0);
    let t_loc = t_total / n;
    let mut total = 0.0;

    // 1) intra-node RS: rank p of node j gets column slice p of the
    //    node-summed contribution.
    let mut scattered: Vec<Vec<Tensor2>> = Vec::with_capacity(n);
    let mut rs_t = 0.0f64;
    for node in 0..n {
        let (slices, t) = reduce_scatter_cols(&contrib[node], cost, CommDomain::IntraNode);
        rs_t = rs_t.max(t); // nodes run in parallel
        scattered.push(slices);
    }
    total += rs_t;

    // 2) inter-node A2A: for each TP rank p, nodes exchange destination
    //    row blocks of their slice (n-way pairwise, m lanes in parallel).
    let mut gathered_slices: Vec<Vec<Tensor2>> = vec![Vec::new(); n];
    let mut a2a_t = 0.0f64;
    for p in 0..m {
        let send: Vec<Vec<Tensor2>> = (0..n)
            .map(|src| {
                (0..n)
                    .map(|dst| scattered[src][p].slice_rows(dst * t_loc..(dst + 1) * t_loc))
                    .collect()
            })
            .collect();
        let (recv, t) = all_to_all_rows(&send, cost, CommDomain::InterNode);
        a2a_t = a2a_t.max(t); // TP lanes ride distinct NIC queues concurrently
        for dst in 0..n {
            // sum contributions from all source nodes for my tokens
            let mut acc = Tensor2::zeros(t_loc, h / m);
            for blk in &recv[dst] {
                acc.add_assign(blk);
            }
            gathered_slices[dst].push(acc);
        }
    }
    total += a2a_t;

    // 3) intra-node AG: reassemble full hidden per node.
    let mut out = Vec::with_capacity(n);
    let mut ag_t = 0.0f64;
    for slices in gathered_slices.iter() {
        let (full, t) = all_gather_cols(slices, cost, CommDomain::IntraNode);
        ag_t = ag_t.max(t);
        out.push(full);
    }
    total += ag_t;
    (out, total)
}

/// Dense reference for the combine: `Y[dst] = Σ_src Σ_tp contrib[src][tp][dst-block]`.
pub fn combine_reference(world: &RankWorld, contrib: &[Vec<Tensor2>]) -> Vec<Tensor2> {
    let n = world.n_nodes;
    let h = contrib[0][0].cols;
    let t_total = contrib[0][0].rows;
    let t_loc = t_total / n;
    (0..n)
        .map(|dst| {
            let mut acc = Tensor2::zeros(t_loc, h);
            for node_bufs in contrib.iter() {
                for buf in node_bufs {
                    acc.add_assign(&buf.slice_rows(dst * t_loc..(dst + 1) * t_loc));
                }
            }
            acc
        })
        .collect()
}

/// Build a deterministic pseudo-random contribution world for tests and
/// benches: `contrib[node][tp]` stacked destination blocks.
pub fn synth_contrib(world: &RankWorld, t_loc: usize, h: usize, seed: u64) -> Vec<Vec<Tensor2>> {
    let (n, m) = (world.n_nodes, world.m_per_node);
    (0..n)
        .map(|node| {
            (0..m)
                .map(|tp| {
                    Tensor2::from_fn(n * t_loc, h, |r, c| {
                        let x = seed
                            .wrapping_mul(6364136223846793005)
                            .wrapping_add((node * 1009 + tp * 31 + r * 7 + c) as u64);
                        ((x >> 33) % 1000) as f32 / 500.0 - 1.0
                    })
                })
                .collect()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::cost::CollectiveCost;
    use crate::config::ClusterConfig;

    fn cost() -> CollectiveCost {
        CollectiveCost::new(&ClusterConfig::ascend910b())
    }

    #[test]
    fn all_reduce_sums_everywhere() {
        let mut bufs: Vec<Tensor2> = (0..4)
            .map(|i| Tensor2::from_fn(3, 4, |r, c| (i + r + c) as f32))
            .collect();
        let t = all_reduce(&mut bufs, &cost(), CommDomain::IntraNode);
        assert!(t > 0.0);
        let want = Tensor2::from_fn(3, 4, |r, c| (0..4).map(|i| (i + r + c) as f32).sum());
        for b in &bufs {
            assert!(b.approx_eq(&want, 1e-6));
        }
    }

    #[test]
    fn rs_then_ag_equals_ar() {
        let bufs: Vec<Tensor2> = (0..4)
            .map(|i| Tensor2::from_fn(2, 8, |r, c| (i * 100 + r * 10 + c) as f32))
            .collect();
        let c = cost();
        let (slices, _) = reduce_scatter_cols(&bufs, &c, CommDomain::IntraNode);
        let (full, _) = all_gather_cols(&slices, &c, CommDomain::IntraNode);
        let mut want = bufs[0].clone();
        for b in &bufs[1..] {
            want.add_assign(b);
        }
        assert!(full.approx_eq(&want, 1e-5));
    }

    #[test]
    fn a2a_transposes_blocks() {
        let d = 3;
        let send: Vec<Vec<Tensor2>> = (0..d)
            .map(|i| {
                (0..d)
                    .map(|j| Tensor2::from_fn(1, 1, |_, _| (i * 10 + j) as f32))
                    .collect()
            })
            .collect();
        let (recv, t) = all_to_all_rows(&send, &cost(), CommDomain::InterNode);
        assert!(t > 0.0);
        for j in 0..d {
            for i in 0..d {
                assert_eq!(recv[j][i].at(0, 0), (i * 10 + j) as f32);
            }
        }
    }

    #[test]
    fn unfused_pipeline_matches_dense_reference() {
        let world = RankWorld::new(4, 2);
        let contrib = synth_contrib(&world, 6, 8, 42);
        let (got, t) = unfused_rs_a2a_ag(&world, &contrib, &cost());
        let want = combine_reference(&world, &contrib);
        assert!(t > 0.0);
        for (g, w) in got.iter().zip(&want) {
            assert!(g.approx_eq(w, 1e-4), "diff {}", g.max_abs_diff(w));
        }
    }
}
