//! The context-free grammar of parallel strategies (§III-B1) — parsing,
//! printing, and exhaustive enumeration.
//!
//! ```text
//! strategy   -> Decoder | Decoder [PP = degree]
//! Decoder    -> Attention, MoE
//! Attention  -> block          (TP and DP)
//! MoE        -> block          (TP and EP; DP excluded: EP over experts
//!                               is already DP among experts)
//! block      -> intra-node + inter-node | parallel
//! intra-node -> parallel
//! inter-node -> parallel
//! parallel   -> TP | EP (DP) = degree
//! degree     -> 2^k (k ∈ ℕ)
//! ```
//!
//! Enumeration is the analyzer's search space: every `(attn, moe, pp)`
//! combination whose degrees are powers of two and whose per-stage device
//! product equals the stage size.

use crate::config::{AttnStrategy, ClusterConfig, MoeStrategy, ParallelStrategy};

/// All power-of-two factorizations `(a, b)` with `a * b == n`.
pub fn pow2_factorizations(n: usize) -> Vec<(usize, usize)> {
    if !n.is_power_of_two() {
        return vec![];
    }
    let mut out = vec![];
    let mut a = 1;
    while a <= n {
        out.push((a, n / a));
        a *= 2;
    }
    out
}

/// Enumerate every grammar-valid strategy for a cluster, over all PP
/// degrees that divide the node count (PP stages are placed on whole
/// nodes, as in the paper's baselines).
pub fn enumerate_strategies(cluster: &ClusterConfig) -> Vec<ParallelStrategy> {
    let total = cluster.total_devices();
    let mut out = vec![];
    let mut pp = 1;
    while pp <= cluster.n_nodes {
        let stage = total / pp;
        if stage == 0 || !stage.is_power_of_two() {
            pp *= 2;
            continue;
        }
        for (attn_tp, attn_dp) in pow2_factorizations(stage) {
            for (moe_tp, moe_ep) in pow2_factorizations(stage) {
                let s = ParallelStrategy {
                    attn: AttnStrategy { tp: attn_tp, dp: attn_dp },
                    moe: MoeStrategy { tp: moe_tp, ep: moe_ep },
                    pp,
                };
                debug_assert!(s.is_valid());
                out.push(s);
            }
        }
        pp *= 2;
    }
    out
}

/// Parse the paper notation produced by `Display`:
/// `TP=a + DP=b, TP=c + EP=d [PP=e]` (each clause optional per grammar).
pub fn parse_strategy(s: &str) -> Result<ParallelStrategy, String> {
    let s = s.trim();
    let (body, pp) = match s.find('[') {
        Some(i) => {
            let tail = s[i..].trim();
            let inner = tail
                .strip_prefix("[PP=")
                .and_then(|t| t.strip_suffix(']'))
                .ok_or_else(|| format!("bad PP clause in {s:?}"))?;
            (s[..i].trim(), inner.trim().parse::<usize>().map_err(|e| e.to_string())?)
        }
        None => (s, 1),
    };
    let (attn_part, moe_part) = body
        .split_once(',')
        .ok_or_else(|| format!("expected `attn, moe` in {s:?}"))?;

    fn parse_block(part: &str) -> Result<Vec<(String, usize)>, String> {
        part.split('+')
            .map(|term| {
                let (k, v) = term
                    .trim()
                    .split_once('=')
                    .ok_or_else(|| format!("bad term {term:?}"))?;
                Ok((
                    k.trim().to_uppercase(),
                    v.trim().parse::<usize>().map_err(|e| e.to_string())?,
                ))
            })
            .collect()
    }

    let attn_terms = parse_block(attn_part)?;
    let moe_terms = parse_block(moe_part)?;
    let mut attn = AttnStrategy { tp: 1, dp: 1 };
    for (k, v) in &attn_terms {
        match k.as_str() {
            "TP" => attn.tp = *v,
            "DP" => attn.dp = *v,
            other => return Err(format!("attention block cannot use {other}")),
        }
    }
    let mut moe = MoeStrategy { tp: 1, ep: 1 };
    for (k, v) in &moe_terms {
        match k.as_str() {
            "TP" => moe.tp = *v,
            "EP" => moe.ep = *v,
            other => return Err(format!("MoE block cannot use {other}")),
        }
    }
    let st = ParallelStrategy { attn, moe, pp };
    if !st.is_valid() {
        return Err(format!("strategy {st} violates grammar constraints"));
    }
    Ok(st)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn factorizations_of_8() {
        assert_eq!(pow2_factorizations(8), vec![(1, 8), (2, 4), (4, 2), (8, 1)]);
        assert!(pow2_factorizations(6).is_empty());
    }

    #[test]
    fn enumeration_counts() {
        // 4x8 = 32 devices: pp=1 -> 6*6, pp=2 -> 5*5, pp=4 -> 4*4
        let c = ClusterConfig::ascend910b();
        let all = enumerate_strategies(&c);
        assert_eq!(all.len(), 36 + 25 + 16);
        assert!(all.iter().all(|s| s.is_valid()));
    }

    #[test]
    fn enumeration_contains_paper_strategies() {
        let c = ClusterConfig::ascend910b();
        let all = enumerate_strategies(&c);
        for want in [
            ParallelStrategy::mixserve(4, 8),
            ParallelStrategy::pure_ep(4, 8),
            ParallelStrategy::tp_pp(8, 4),
        ] {
            assert!(all.contains(&want), "missing {want}");
        }
    }

    #[test]
    fn parse_roundtrip() {
        for s in [
            ParallelStrategy::mixserve(2, 4),
            ParallelStrategy::pure_ep(4, 8),
            ParallelStrategy::tp_pp(8, 2),
        ] {
            let text = s.to_string();
            assert_eq!(parse_strategy(&text).unwrap(), s, "{text}");
        }
    }

    #[test]
    fn parse_deepseek_v3_prefill_notation() {
        // §III-B1: "the parallelism strategy for the prefill phase is
        // TP=4 + DP=8, EP=32"
        let s = parse_strategy("TP=4 + DP=8, EP=32").unwrap();
        assert_eq!(s.attn, AttnStrategy { tp: 4, dp: 8 });
        assert_eq!(s.moe, MoeStrategy { tp: 1, ep: 32 });
    }

    #[test]
    fn parse_rejects_dp_in_moe() {
        assert!(parse_strategy("TP=4 + DP=8, DP=32").is_err());
    }

    #[test]
    fn parse_rejects_mismatched_degrees() {
        assert!(parse_strategy("TP=4 + DP=2, EP=4").is_err());
    }
}
