//! Memory feasibility — §III-B4 Constraints, Eq. (8):
//!
//!   Ψ_Attn/d_TP + Ψ_MoE/(d_EP·d_TP) + 2·b·s·h·(l/d_PP) < M

use crate::config::{ClusterConfig, MoEModelConfig, ParallelStrategy};

/// Fraction of device memory usable for weights + KV cache (the rest is
/// activation workspace / allocator headroom — vLLM's
/// `gpu_memory_utilization` defaults to the same 0.9).
pub const MEM_UTILIZATION: f64 = 0.9;

#[derive(Debug, Clone, Copy)]
pub struct MemoryCheck {
    pub weights_bytes: u64,
    pub kv_bytes: u64,
    pub limit_bytes: u64,
}

impl MemoryCheck {
    pub fn feasible(&self) -> bool {
        self.weights_bytes + self.kv_bytes < self.limit_bytes
    }

    pub fn total(&self) -> u64 {
        self.weights_bytes + self.kv_bytes
    }
}

/// Evaluate Eq. (8) for one device under `strategy`.
pub fn check_memory(
    model: &MoEModelConfig,
    cluster: &ClusterConfig,
    strategy: &ParallelStrategy,
    batch: usize,
    seq: usize,
) -> MemoryCheck {
    let layers_per_stage =
        (model.n_layers as f64 / strategy.pp as f64).ceil() as u64;
    let dt = model.dtype_bytes as u64;

    let attn_w = model.attn_params_per_layer() / strategy.attn.tp as u64;
    let moe_w = model.moe_params_per_layer()
        / (strategy.moe.ep as u64 * strategy.moe.tp as u64);
    // shared experts + router replicate under EP, shard under MoE TP
    let shared_w = model.shared_params_per_layer() / strategy.moe.tp as u64;
    let embed_w = 2 * (model.vocab * model.hidden) as u64 / strategy.attn.tp as u64;
    let weights_bytes =
        ((attn_w + moe_w + shared_w) * layers_per_stage + embed_w) * dt;

    // KV cache: per-DP-replica batch rows, sharded over the attention TP
    // group, only this stage's layers.
    let rows = (batch as f64 / strategy.attn.dp as f64).ceil() as u64;
    let kv_per_tok = 2 * (model.n_kv_heads * model.head_dim) as u64 * dt;
    let kv_bytes = rows * seq as u64 * kv_per_tok * layers_per_stage
        / strategy.attn.tp as u64;

    let limit_bytes = (cluster.mem_bytes as f64 * MEM_UTILIZATION) as u64;
    MemoryCheck { weights_bytes, kv_bytes, limit_bytes }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deepseek_on_one_device_infeasible() {
        let m = MoEModelConfig::deepseek_r1();
        let c = ClusterConfig::ascend910b();
        let s = ParallelStrategy::mixserve(1, 1);
        assert!(!check_memory(&m, &c, &s, 16, 4096).feasible());
    }

    #[test]
    fn deepseek_on_32_devices_feasible_with_ep() {
        let m = MoEModelConfig::deepseek_r1();
        let c = ClusterConfig::ascend910b();
        // the paper's vLLM DP+EP config: TP=8 + DP=4, EP=32
        let s = ParallelStrategy::pure_ep(4, 8);
        let chk = check_memory(&m, &c, &s, 16, 4096);
        assert!(chk.feasible(), "weights {}GB kv {}GB", chk.weights_bytes >> 30, chk.kv_bytes >> 30);
    }

    #[test]
    fn higher_ep_means_less_weight_memory() {
        let m = MoEModelConfig::qwen3_235b();
        let c = ClusterConfig::ascend910b();
        let a = check_memory(&m, &c, &ParallelStrategy::mixserve(4, 8), 16, 4096);
        let b = check_memory(&m, &c, &ParallelStrategy::pure_ep(4, 8), 16, 4096);
        // pure EP=32 shards routed experts over 32 vs hybrid's tp8·ep4=32:
        // equal expert shards, but hybrid also TP-shards attention... both
        // must at least be feasible and nonzero.
        assert!(a.weights_bytes > 0 && b.weights_bytes > 0);
    }

    #[test]
    fn kv_scales_with_batch_and_seq() {
        let m = MoEModelConfig::qwen3_235b();
        let c = ClusterConfig::h20();
        let s = ParallelStrategy::mixserve(2, 8);
        let small = check_memory(&m, &c, &s, 4, 512).kv_bytes;
        let big = check_memory(&m, &c, &s, 16, 4096).kv_bytes;
        assert!(big >= small * 8);
    }

    #[test]
    fn pp_divides_layer_weights() {
        let m = MoEModelConfig::deepseek_r1();
        let c = ClusterConfig::ascend910b();
        let flat = check_memory(&m, &c, &ParallelStrategy::tp_pp(8, 1), 8, 1024);
        let piped = check_memory(&m, &c, &ParallelStrategy::tp_pp(8, 4), 8, 1024);
        assert!(piped.weights_bytes < flat.weights_bytes / 2);
    }
}
