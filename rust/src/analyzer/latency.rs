//! Token-generation latency model — §III-B4, Eqs. (4)–(6), plus the
//! hybrid-vs-pure communication overheads of §III-C2, Eqs. (12)–(13).
//!
//! All communication is timed through the [`CommCost`] trait (the
//! unified timing layer): the model is generic over the cost backend, so
//! the same Eq. (5)/(12)/(13) arithmetic prices strategies under the
//! analytic α–β model *or* the contention-aware NetSim-backed one.  The
//! MoE block's λ is load-aware: an [`ExpertLoadProfile`] scales the
//! dispatch/combine volume by the *hot rank's* share (max load), not the
//! uniform-placement mean — the §I imbalance finally reaching Eq. (5).

use crate::comm::cost::CollectiveCost;
use crate::config::{ClusterConfig, MoEModelConfig, ParallelStrategy};
use crate::gantt::Lane;
use crate::pipeline::{chunked_pipeline, HybridStage, PipelineCfg};
use crate::timing::schedule::{backend_combine_ir, backend_dispatch_ir, EpShape, Schedule, Step};
use crate::timing::{
    agmask_exchange_time, remote_group_copies, CommCost, CommDomain, DispatchBackend,
    ExpertLoadProfile,
};

/// Prefill processes the full prompt; decode one token with a cached
/// context (Eqs. 9–10 evaluate Δt_svc at s = L_in and s = 1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    Prefill,
    Decode,
}

/// Communication schedule used for the MoE block.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CommMode {
    /// back-to-back collectives (baselines; MixServe's sync ablation)
    Sync,
    /// fused AR-A2A with intra/inter overlap (Algorithms 1–2)
    FusedAsync,
}

/// Composition of one mixed serving iteration (a chunked-prefill
/// engine's unit of work): prompt-slice tokens riding the same forward
/// pass as the running decodes.  Priced by
/// [`LatencyModel::mixed_iteration`].
#[derive(Debug, Clone, Copy)]
pub struct MixedIter {
    /// prompt slices in the iteration
    pub prefill_reqs: usize,
    /// total prompt tokens across the slices
    pub prefill_tokens: usize,
    /// attention prefix of the deepest slice (its tokens attend over
    /// this much context)
    pub prefill_seq: usize,
    /// running decode requests (one token each)
    pub decode_reqs: usize,
    /// mean cached context of the decoding requests
    pub decode_ctx: usize,
}

/// Per-token latency breakdown of one decoder layer set.
#[derive(Debug, Clone, Copy)]
pub struct LatencyBreakdown {
    /// computational latency τ (Eq. 4), seconds
    pub compute: f64,
    /// communication latency λ (Eq. 5 / 12 / 13), seconds
    pub comm: f64,
    /// PP bubble (Eq. 6 P2P term), seconds
    pub p2p: f64,
    /// seconds hidden by chunked micro-batch pipelining of the MoE
    /// block (0 when pipelining is off — today's additive pricing)
    pub overlap: f64,
}

impl LatencyBreakdown {
    pub fn total(&self) -> f64 {
        self.compute + self.comm + self.p2p - self.overlap
    }
}

/// The analyzer's latency model, bound to (model, cluster, cost backend,
/// expert-load profile).
#[derive(Debug, Clone)]
pub struct LatencyModel<C: CommCost = CollectiveCost> {
    pub model: MoEModelConfig,
    pub cluster: ClusterConfig,
    pub cost: C,
    pub load: ExpertLoadProfile,
    /// chunked micro-batch pipelining of the MoE block (default Off:
    /// the historical additive pricing, bit-for-bit)
    pub pipeline: PipelineCfg,
    /// dispatch/combine algorithm for the MoE exchange (default
    /// `AllToAll`: the fused pairwise shape, bit-for-bit)
    pub backend: DispatchBackend,
}

impl LatencyModel<CollectiveCost> {
    pub fn new(model: &MoEModelConfig, cluster: &ClusterConfig) -> Self {
        Self::with_cost(model, cluster, CollectiveCost::new(cluster))
    }
}

impl<C: CommCost> LatencyModel<C> {
    /// Bind the model to an explicit cost backend (uniform load).
    pub fn with_cost(model: &MoEModelConfig, cluster: &ClusterConfig, cost: C) -> Self {
        Self {
            model: model.clone(),
            cluster: cluster.clone(),
            cost,
            load: ExpertLoadProfile::uniform(model.n_experts),
            pipeline: PipelineCfg::Off,
            backend: DispatchBackend::AllToAll,
        }
    }

    /// Price λ under this expert-load profile (builder style).
    pub fn with_load(mut self, load: ExpertLoadProfile) -> Self {
        self.load = load;
        self
    }

    /// Price the MoE block under chunked micro-batch pipelining
    /// (builder style; `PipelineCfg::Off` reproduces the additive
    /// pricing bit-for-bit).
    pub fn with_pipeline(mut self, pipeline: PipelineCfg) -> Self {
        self.pipeline = pipeline;
        self
    }

    /// Swap the pipeline config in place (the serving simulator's knob).
    pub fn set_pipeline(&mut self, pipeline: PipelineCfg) {
        self.pipeline = pipeline;
    }

    /// Price the MoE exchange under this dispatch/combine backend
    /// (builder style; `DispatchBackend::AllToAll` reproduces the fused
    /// pairwise pricing bit-for-bit).
    pub fn with_backend(mut self, backend: DispatchBackend) -> Self {
        self.backend = backend;
        self
    }

    /// Swap the dispatch backend in place (the analyzer's joint
    /// strategy × backend search re-prices one model per candidate).
    pub fn set_backend(&mut self, backend: DispatchBackend) {
        self.backend = backend;
    }

    /// Swap the load profile in place (per-iteration re-pricing in the
    /// serving simulator).
    pub fn set_load(&mut self, load: ExpertLoadProfile) {
        self.load = load;
    }

    /// Tokens processed per iteration by one DP replica: batch rows b/d_DP,
    /// each contributing `s` positions in prefill or 1 in decode.
    fn tokens_per_dp(&self, s: &ParallelStrategy, batch: usize, seq: usize, phase: Phase) -> f64 {
        let rows = (batch as f64 / s.attn.dp as f64).max(1.0);
        match phase {
            Phase::Prefill => rows * seq as f64,
            Phase::Decode => rows,
        }
    }

    /// Expert-GEMM efficiency under sharding.  DeepSeek-V3's case for EP:
    /// "each expert [must] process sufficiently large batch sizes, thereby
    /// maximizing computational efficiency."  TP-slicing the expert FFN
    /// (width f/d_TP) and starving experts of tokens both collapse MFU;
    /// modeled as a saturating product in per-expert rows M and slice
    /// width W.
    pub fn expert_gemm_efficiency(&self, s: &ParallelStrategy, global_toks: f64) -> f64 {
        const M_SAT: f64 = 64.0; // rows to saturate the MAC array
        const W_SAT: f64 = 32.0; // slice width to keep the MAC array fed
        let m = &self.model;
        let rows_per_expert =
            global_toks * m.top_k as f64 / m.n_experts as f64;
        let width = m.expert_inter as f64 / s.moe.tp as f64;
        (rows_per_expert / (rows_per_expert + M_SAT))
            * (width / (width + W_SAT))
    }

    /// Computational latency τ — Eq. (4): work shrinks with d_TP·d_EP and
    /// the per-replica batch with d_DP; decode is additionally floored by
    /// the HBM roofline (streaming the activated expert weights).
    pub fn compute_latency(
        &self,
        s: &ParallelStrategy,
        batch: usize,
        seq: usize,
        phase: Phase,
    ) -> f64 {
        let m = &self.model;
        let eff_flops = self.cluster.flops * self.cluster.mfu;
        let (attn_f, _) = m.flops_per_token_layer(seq);
        let toks = self.tokens_per_dp(s, batch, seq, phase);
        // attention work is sharded by the attention TP group
        let attn = toks * attn_f / s.attn.tp as f64;
        let moe_t = self.moe_compute_chunk(s, batch, seq, phase, 1);
        let layers_total = m.n_layers as f64;
        (attn / eff_flops + moe_t) * layers_total
    }

    /// One layer's expert-GEMM time for a 1/`chunks` micro-batch slice —
    /// Eq. (4)'s MoE term evaluated on the chunk.  `chunks == 1` is the
    /// exact per-layer MoE compute inside [`LatencyModel::compute_latency`].
    ///
    /// The chunking trade-off shows up here: a 1/K slice feeds each
    /// expert 1/K of the rows, so the GroupGEMM efficiency drops
    /// (EPS-MoE's reason not to over-chunk), while the HBM
    /// weight-streaming floor amortizes across the chunks (the expert
    /// weights stay resident for the iteration).
    pub fn moe_compute_chunk(
        &self,
        s: &ParallelStrategy,
        batch: usize,
        seq: usize,
        phase: Phase,
        chunks: usize,
    ) -> f64 {
        let (_, moe_f) = self.model.flops_per_token_layer(seq);
        let toks = self.tokens_per_dp(s, batch, seq, phase);
        self.moe_compute_tokens(s, toks, moe_f, chunks)
    }

    /// The MoE-compute core of [`LatencyModel::moe_compute_chunk`],
    /// parameterized by the raw per-DP-replica token count — shared with
    /// the mixed-iteration pricing, where the token set is a composition
    /// of prefill-chunk and decode tokens rather than one (batch, seq,
    /// phase) group.  `chunks == 1` with `toks = tokens_per_dp(...)`
    /// reproduces the historical arithmetic exactly.
    pub fn moe_compute_tokens(
        &self,
        s: &ParallelStrategy,
        toks: f64,
        moe_f: f64,
        chunks: usize,
    ) -> f64 {
        let m = &self.model;
        let eff_flops = self.cluster.flops * self.cluster.mfu;
        let k = chunks.max(1) as f64;
        // expert work: the communicator processes d_DP replicas' tokens,
        // spread over the moe.tp × moe.ep grid (Eq. 4's Ψ/(d_TP·d_EP)),
        // derated by the expert-GEMM efficiency.
        let global_toks = toks * s.attn.dp as f64 / k;
        let eff = self.expert_gemm_efficiency(s, global_toks);
        let moe = global_toks * moe_f / (s.moe.tp * s.moe.ep) as f64 / eff.max(1e-3);
        // HBM floor: every activated expert's weights stream from HBM once
        // per iteration on each device holding them.
        let experts_per_device =
            (m.n_experts as f64 / s.moe.ep as f64).max(1.0);
        let touched = experts_per_device
            .min(global_toks * k * m.top_k as f64 / s.moe.ep as f64)
            .max(1.0);
        let expert_bytes = 3.0
            * (m.hidden * m.expert_inter * m.dtype_bytes) as f64
            / s.moe.tp as f64;
        let hbm_floor = touched * expert_bytes / self.cluster.hbm_bw / k;
        (moe / eff_flops).max(hbm_floor)
    }

    /// Bytes of one replica's activation tensor (b/d_DP · s · h).
    fn act_bytes(&self, s: &ParallelStrategy, batch: usize, seq: usize, phase: Phase) -> f64 {
        self.tokens_per_dp(s, batch, seq, phase)
            * (self.model.hidden * self.model.dtype_bytes) as f64
    }

    /// Expected activation copies a token ships to *remote* EP groups
    /// (one copy per destination group — the hybrid's volume saving; see
    /// [`remote_group_copies`] in the timing layer).
    pub fn remote_copies(&self, groups: usize) -> f64 {
        remote_group_copies(groups, self.model.top_k)
    }

    /// Communication latency λ of one layer — Eq. (5) with the §III-B3
    /// DP/EP trade-off, Eq. (12) for pure EP, Eq. (13) for the hybrid,
    /// the fused overlap when `mode == FusedAsync`, and the load
    /// profile's hot-rank factor scaling the EP dispatch/combine volume.
    pub fn comm_latency_layer(
        &self,
        s: &ParallelStrategy,
        batch: usize,
        seq: usize,
        phase: Phase,
        mode: CommMode,
    ) -> f64 {
        let c = &self.cost;
        let bytes = self.act_bytes(s, batch, seq, phase);

        // ---- attention block: one AR per layer over the attention TP group
        let attn_ar = c.all_reduce(bytes, s.attn.tp, c.domain_of(s.attn.tp));

        attn_ar + self.moe_comm_layer(s, batch, seq, phase, mode)
    }

    /// Per-NIC and per-fabric hot-rank lane volumes of the rank-granular
    /// pure-EP dispatch (the Eq. 12 lane model), shared by the additive
    /// pricing and the chunked pipeline.
    fn pure_ep_lane_volumes(&self, ep: usize, global_bytes: f64, hot: f64) -> (f64, f64) {
        let g = ep as f64;
        let distinct = crate::timing::expected_distinct_groups(ep, self.model.top_k);
        let m_node = self.cluster.gpus_per_node.min(ep) as f64;
        let nodes_spanned = (g / m_node).max(1.0);
        let off_frac = if ep <= self.cluster.gpus_per_node {
            0.0
        } else {
            (g - m_node) / g
        };
        let per_nic = global_bytes * distinct * off_frac / nodes_spanned * hot;
        let per_fabric = global_bytes * distinct * (1.0 - off_frac) / nodes_spanned * hot;
        (per_nic, per_fabric)
    }

    /// The MoE block's share of one layer's λ (everything of
    /// [`LatencyModel::comm_latency_layer`] except the attention AR).
    pub fn moe_comm_layer(
        &self,
        s: &ParallelStrategy,
        batch: usize,
        seq: usize,
        phase: Phase,
        mode: CommMode,
    ) -> f64 {
        self.moe_comm_bytes(s, self.act_bytes(s, batch, seq, phase), mode)
    }

    /// The MoE-communication core of [`LatencyModel::moe_comm_layer`],
    /// parameterized by the raw per-replica activation bytes — shared
    /// with the mixed-iteration pricing, which routes the *combined*
    /// prefill-chunk + decode volume through one Eq. (12)/(13) pass.
    pub fn moe_comm_bytes(&self, s: &ParallelStrategy, bytes: f64, mode: CommMode) -> f64 {
        let c = &self.cost;

        // ---- MoE block.  The MoE communicator carries the *global* token
        // set of all DP replicas (b·s·h), spread over the moe.tp × moe.ep
        // grid — this is why AR-based pure TP collapses at high degree
        // (Fig. 3) while EP only ships top-k-selected rows.  Under skew
        // the collective completes when the *hot* rank's volume lands:
        // the profile's max/mean factor scales the EP-bound volume.
        let global_bytes = bytes * s.attn.dp as f64;
        let (tp, ep) = (s.moe.tp, s.moe.ep);
        let hot = self.load.hot_factor(ep);
        if ep == 1 {
            // pure TP: every token's FFN sharded over all tp devices; one
            // AR of the full activation volume per layer (skew-immune —
            // every device serves every expert).  No dispatch/combine
            // exists, so this branch is backend-invariant.
            c.all_reduce(global_bytes, tp, c.domain_of(tp))
        } else if self.backend == DispatchBackend::AllGatherMask {
            // AG-dispatch + RS-combine over the EP communicator: gather
            // the FULL global activation on every rank and mask locally.
            // No per-peer launches (one collective α per direction) but
            // no routing dedup either — and skew-immune, since every
            // rank gathers everything regardless of expert popularity.
            // The strided tp×ep group spans nodes iff tp·ep does.
            agmask_exchange_time(c, global_bytes, ep, tp * ep, c.domain_of(tp * ep))
        } else if tp == 1 {
            // pure EP: rank-granular dispatch/combine.  Every *distinct
            // activated rank* receives its own copy of the token's hidden
            // state — two experts on different ranks of the same remote
            // node cross the wire twice (the hybrid crosses once, its
            // volume saving).  Off-node copies ride the NIC, on-node ones
            // the fabric; Pairwise needs d−1 launch rounds (the EP
            // pathology at high degree), and the hot rank's inflated
            // share gates both lanes.
            let d = ep;
            // per_nic already aggregates every local rank's traffic onto
            // the node's NIC (÷ nodes_spanned, not ÷ ranks), so this lane
            // model is per-link-traffic-aware by construction: sharers = 1
            // or a contention-aware backend would double-count.
            let (per_nic, per_fabric) = self.pure_ep_lane_volumes(d, global_bytes, hot);
            // the backend reshapes the lane model: launch count per the
            // kernel's round structure, wire at its effective bandwidth
            // (`AllToAll` keeps d−1 rounds at factor 1.0 — bit-for-bit)
            let rounds = self.backend.launch_rounds(d - 1);
            let wf = self.backend.wire_factor();
            let t_inter = c.pairwise_rounds(rounds, per_nic * wf, 1, CommDomain::InterNode);
            let t_intra = c.wire(per_fabric * wf, 1, CommDomain::IntraNode);
            // dispatch + combine; intra and inter lanes progress together
            2.0 * t_inter.max(t_intra)
        } else {
            // hybrid TP-EP (§III-C2, Eq. 13): TP intra-node, EP inter-node.
            // One copy per destination *node* — the hybrid's volume saving.
            let vol = global_bytes * self.remote_copies(ep).max(1e-9) / ep as f64 * hot;
            let blk = vol / (ep as f64 - 1.0).max(1.0);
            // the TP group's RS/AG stay intra-node only while tp fits in a
            // node — oversized TP groups pay the NIC (Fig. 3's d > 8 wall)
            let tp_domain = c.domain_of(tp);
            // Algorithms 1–2 as the shared schedule IR — reshaped per
            // dispatch backend (`AllToAll` delegates to the plain
            // builders verbatim), played under the bound cost backend
            // (async) or summed per lane (sync).
            let shape = EpShape {
                nodes: 1,
                rounds: ep,
                tp,
                tp_domain,
                ep_domain: c.domain_of(tp * ep),
            };
            let disp = backend_dispatch_ir(self.backend, &shape, blk, blk);
            let comb = backend_combine_ir(self.backend, &shape, blk, bytes);
            let (disp_async, disp_sync) = disp.makespans(c);
            let (comb_async, comb_sync) = comb.makespans(c);
            match mode {
                CommMode::Sync => disp_sync + comb_sync,
                CommMode::FusedAsync => disp_async + comb_async,
            }
        }
    }

    /// Overlapped makespan of one layer's MoE block split into `chunks`
    /// micro-batch chunks: dispatch comm, expert GroupGEMM, and combine
    /// comm pipelined over the lane/stream resources, so Eq. (13)'s
    /// pricing becomes max(comm, compute) per pipeline stage instead of
    /// their sum.  `chunks == 1` reproduces the additive
    /// `moe_comm_layer + moe_compute_chunk` time (no overlap to exploit
    /// between dependent stages of one chunk).
    pub fn moe_pipelined_layer(
        &self,
        s: &ParallelStrategy,
        batch: usize,
        seq: usize,
        phase: Phase,
        chunks: usize,
    ) -> f64 {
        let c = &self.cost;
        let k = chunks.max(1);
        let (tp, ep) = (s.moe.tp, s.moe.ep);
        let gemm_chunk = self.moe_compute_chunk(s, batch, seq, phase, k);
        if ep <= 1 || self.backend == DispatchBackend::AllGatherMask {
            // pure TP: a single AR, no dispatch/compute/combine chain to
            // pipeline — additive, chunk-independent.  AllGatherMask is
            // the same shape for a different reason: its exchange is two
            // monolithic collectives, so there is no round structure for
            // micro-chunks to overlap against.
            return self.moe_comm_layer(s, batch, seq, phase, CommMode::FusedAsync)
                + self.moe_compute_chunk(s, batch, seq, phase, 1);
        }
        let bytes = self.act_bytes(s, batch, seq, phase);
        let global_bytes = bytes * s.attn.dp as f64;
        let hot = self.load.hot_factor(ep);
        if tp == 1 {
            // rank-granular pure EP: each chunk still pays all d−1 launch
            // rounds on the NIC lane (only the wire time splits), which is
            // exactly why low-batch high-degree EP pipelines poorly
            let (per_nic, per_fabric) = self.pure_ep_lane_volumes(ep, global_bytes, hot);
            let kf = k as f64;
            let rounds = self.backend.launch_rounds(ep - 1);
            let wf = self.backend.wire_factor();
            let t_inter = c.pairwise_rounds(rounds, per_nic * wf / kf, 1, CommDomain::InterNode);
            let t_intra = c.wire(per_fabric * wf / kf, 1, CommDomain::IntraNode);
            let dir = t_inter.max(t_intra);
            let sched = chunked_pipeline(
                k,
                1,
                |ci| {
                    let mut sub = Schedule::default();
                    sub.push(Step::elapsed(Lane::Inter(0), format!("D{ci}"), dir, vec![]));
                    sub
                },
                |ci, node| {
                    Step::elapsed(Lane::Stream(node, 0), format!("G{ci}"), gemm_chunk, vec![])
                },
                |ci| {
                    let mut sub = Schedule::default();
                    sub.push(Step::elapsed(Lane::Inter(0), format!("C{ci}"), dir, vec![]));
                    sub
                },
            );
            return sched.makespans(c).0;
        }
        // hybrid TP-EP: Algorithms 1–2 chunked (same blk/AG volumes as
        // moe_comm_layer, 1/K per chunk), GroupGEMM on the node stream
        let vol = global_bytes * self.remote_copies(ep).max(1e-9) / ep as f64 * hot;
        let blk = vol / (ep as f64 - 1.0).max(1.0);
        let stage = HybridStage {
            nodes: 1,
            rounds: ep,
            tp,
            tp_domain: c.domain_of(tp),
            disp_blk_bytes: blk,
            comb_blk_bytes: blk,
            comb_ag_bytes: bytes,
            flops: 0.0, // per-chunk cost passed explicitly below
            backend: self.backend,
        };
        let rate = (self.cluster.flops * self.cluster.mfu).max(1.0);
        stage.schedule_with(k, gemm_chunk * rate).makespans(c).0
    }

    /// Seconds of one layer's MoE time hidden by chunked micro-batch
    /// pipelining relative to the additive pricing (negative when a
    /// forced `--chunks` count genuinely costs time: extra launch rounds
    /// and a starved GroupGEMM).  Zero when pipelining is off, under
    /// Sync schedules (nothing overlaps), or without an EP dimension.
    pub fn overlap_saving_layer(
        &self,
        s: &ParallelStrategy,
        batch: usize,
        seq: usize,
        phase: Phase,
        mode: CommMode,
    ) -> f64 {
        if self.pipeline.is_off() || mode != CommMode::FusedAsync || s.moe.ep <= 1 {
            return 0.0;
        }
        let serial = self.moe_comm_layer(s, batch, seq, phase, mode)
            + self.moe_compute_chunk(s, batch, seq, phase, 1);
        let mut best = f64::INFINITY;
        for k in self.pipeline.candidates() {
            // K = 1 is the additive chain by construction (pinned by
            // one_chunk_reproduces_additive_moe_pricing): skip the
            // schedule build on the simulator's per-iteration hot path
            let t = if k == 1 {
                serial
            } else {
                self.moe_pipelined_layer(s, batch, seq, phase, k)
            };
            best = best.min(t);
        }
        let saving = serial - best;
        match self.pipeline {
            // the auto search includes K = 1 (== serial): clamp float
            // noise so Auto never prices a loss
            PipelineCfg::Auto => saving.max(0.0),
            _ => saving,
        }
    }

    /// Price one *mixed* serving iteration — Eqs. (12)–(13) evaluated on
    /// the combined batch of a chunked-prefill engine: `prefill_tokens`
    /// prompt-slice tokens and `decode_reqs` decode tokens share one
    /// forward pass per layer, so the iteration pays ONE attention
    /// all-reduce, ONE dispatch/combine at the combined activation
    /// volume, ONE GroupGEMM over the combined token set (the chunk
    /// tokens top up the decode batch's starved experts — the EPS-MoE
    /// argument), and ONE expert-weight stream from HBM — where the
    /// historical engine runs the prefill and decode groups as two
    /// passes and pays each fixed cost twice.  With no prefill component
    /// this reproduces the decode-phase [`LatencyModel::service_latency`]
    /// (pipelining off); the micro-chunk overlap saving is not priced on
    /// mixed iterations (the composition already interleaves at the
    /// scheduler level).
    pub fn mixed_iteration(
        &self,
        s: &ParallelStrategy,
        mix: &MixedIter,
        mode: CommMode,
    ) -> LatencyBreakdown {
        let m = &self.model;
        let eff_flops = self.cluster.flops * self.cluster.mfu;
        let dp = s.attn.dp as f64;
        // per-DP-replica token load of each component, with the same
        // floor-at-one-row guard as `tokens_per_dp`
        let p_toks = if mix.prefill_reqs == 0 {
            0.0
        } else {
            (mix.prefill_reqs as f64 / dp).max(1.0) * mix.prefill_tokens as f64
                / mix.prefill_reqs as f64
        };
        let d_toks = if mix.decode_reqs == 0 {
            0.0
        } else {
            (mix.decode_reqs as f64 / dp).max(1.0)
        };
        let toks = p_toks + d_toks;
        if toks <= 0.0 {
            return LatencyBreakdown { compute: 0.0, comm: 0.0, p2p: 0.0, overlap: 0.0 };
        }
        // attention compute stays per-component: slice tokens attend over
        // their prompt prefix, decode rows over the cached context
        let (attn_p, moe_f) = m.flops_per_token_layer(mix.prefill_seq.max(1));
        let (attn_d, _) = m.flops_per_token_layer(mix.decode_ctx.max(1));
        let attn = (p_toks * attn_p + d_toks * attn_d) / s.attn.tp as f64;
        let moe_t = self.moe_compute_tokens(s, toks, moe_f, 1);
        let compute = (attn / eff_flops + moe_t) * m.n_layers as f64;
        // one collective pass per layer over the combined volume
        let bytes = toks * (m.hidden * m.dtype_bytes) as f64;
        let attn_ar = self.cost.all_reduce(bytes, s.attn.tp, self.cost.domain_of(s.attn.tp));
        let comm = (attn_ar + self.moe_comm_bytes(s, bytes, mode)) * m.n_layers as f64;
        let p2p = if s.pp > 1 {
            (s.pp as f64 - 1.0) * self.cost.p2p(bytes)
        } else {
            0.0
        };
        LatencyBreakdown { compute, comm, p2p, overlap: 0.0 }
    }

    /// Service latency per token — Eq. (6):
    /// Δt_svc = l·[τ + λ] + (d_PP − 1) · P2P(b/d_DP · s · h),
    /// minus the per-layer pipelining saving when chunking is enabled.
    pub fn service_latency(
        &self,
        s: &ParallelStrategy,
        batch: usize,
        seq: usize,
        phase: Phase,
        mode: CommMode,
    ) -> LatencyBreakdown {
        let compute = self.compute_latency(s, batch, seq, phase);
        let comm =
            self.comm_latency_layer(s, batch, seq, phase, mode) * self.model.n_layers as f64;
        let p2p = if s.pp > 1 {
            (s.pp as f64 - 1.0) * self.cost.p2p(self.act_bytes(s, batch, seq, phase))
        } else {
            0.0
        };
        let overlap =
            self.overlap_saving_layer(s, batch, seq, phase, mode) * self.model.n_layers as f64;
        LatencyBreakdown { compute, comm, p2p, overlap }
    }

    /// The pure-EP deployment's per-layer communication — Eq. (12)
    /// (used by Fig. 4's Gantt comparison).
    pub fn lambda_pure_ep(&self, batch: usize, seq: usize) -> f64 {
        let c = &self.cost;
        let n_proc = self.cluster.gpus_per_node;
        let n_node = self.cluster.n_nodes;
        let bytes = (batch * seq * self.model.hidden * self.model.dtype_bytes) as f64
            / n_node as f64;
        let k = self.model.top_k as f64;
        c.all_reduce(bytes, n_proc, CommDomain::IntraNode)
            + 2.0 * c.all_to_all(bytes * k, n_node * n_proc, CommDomain::InterNode)
    }

    /// The hybrid deployment's per-layer communication — Eq. (13).
    pub fn lambda_mix(&self, batch: usize, seq: usize, mode: CommMode) -> f64 {
        let s = ParallelStrategy::mixserve(self.cluster.n_nodes, self.cluster.gpus_per_node);
        self.comm_latency_layer(&s, batch, seq, Phase::Prefill, mode)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::timing::NetSimCost;

    fn lm() -> LatencyModel {
        LatencyModel::new(&MoEModelConfig::deepseek_r1(), &ClusterConfig::ascend910b())
    }

    #[test]
    fn compute_scales_down_with_parallelism() {
        let m = lm();
        let s1 = ParallelStrategy::mixserve(1, 1);
        let s8 = ParallelStrategy::mixserve(4, 8);
        let t1 = m.compute_latency(&s1, 16, 512, Phase::Prefill);
        let t8 = m.compute_latency(&s8, 16, 512, Phase::Prefill);
        assert!(t8 < t1 / 4.0, "32 devices must be >4x faster: {t1} vs {t8}");
    }

    #[test]
    fn prefill_dominates_decode() {
        let m = lm();
        let s = ParallelStrategy::mixserve(4, 8);
        let p = m.service_latency(&s, 16, 1024, Phase::Prefill, CommMode::FusedAsync);
        let d = m.service_latency(&s, 16, 1024, Phase::Decode, CommMode::FusedAsync);
        assert!(p.total() > 10.0 * d.total());
    }

    #[test]
    fn fused_async_no_slower_than_sync() {
        let m = lm();
        let s = ParallelStrategy::mixserve(4, 8);
        for (b, l) in [(4, 256), (16, 1024), (16, 4096)] {
            let sync = m.service_latency(&s, b, l, Phase::Prefill, CommMode::Sync);
            let fused = m.service_latency(&s, b, l, Phase::Prefill, CommMode::FusedAsync);
            assert!(fused.comm <= sync.comm * 1.0001, "b={b} l={l}");
        }
    }

    #[test]
    fn hybrid_beats_pure_ep_on_paper_clusters() {
        // §III-C2's claim: λ_mix < λ_EP on bandwidth-hierarchical clusters.
        for cl in [ClusterConfig::ascend910b(), ClusterConfig::h20()] {
            let m = LatencyModel::new(&MoEModelConfig::deepseek_r1(), &cl);
            let pure = m.lambda_pure_ep(16, 1024);
            let mix = m.lambda_mix(16, 1024, CommMode::FusedAsync);
            assert!(mix < pure, "{}: mix {mix} !< pure {pure}", cl.name);
        }
    }

    #[test]
    fn pp_adds_p2p_bubble() {
        let m = lm();
        let flat = ParallelStrategy::tp_pp(8, 1);
        let piped = ParallelStrategy::tp_pp(8, 4);
        let a = m.service_latency(&flat, 8, 512, Phase::Prefill, CommMode::Sync);
        let b = m.service_latency(&piped, 8, 512, Phase::Prefill, CommMode::Sync);
        assert_eq!(a.p2p, 0.0);
        assert!(b.p2p > 0.0);
    }

    #[test]
    fn decode_comm_smaller_than_prefill_comm() {
        let m = lm();
        let s = ParallelStrategy::pure_ep(4, 8);
        let p = m.comm_latency_layer(&s, 16, 2048, Phase::Prefill, CommMode::Sync);
        let d = m.comm_latency_layer(&s, 16, 2048, Phase::Decode, CommMode::Sync);
        assert!(d < p);
    }

    #[test]
    fn uniform_profile_prices_like_no_profile() {
        // hot factor 1 must be a no-op: the skew-aware path reproduces
        // the historical uniform-mean pricing bit-for-bit
        let m = lm();
        let explicit = m
            .clone()
            .with_load(ExpertLoadProfile::uniform(m.model.n_experts));
        for s in [
            ParallelStrategy::mixserve(4, 8),
            ParallelStrategy::pure_ep(4, 8),
            ParallelStrategy::tp_pp(8, 4),
        ] {
            for mode in [CommMode::Sync, CommMode::FusedAsync] {
                let a = m.comm_latency_layer(&s, 16, 1024, Phase::Prefill, mode);
                let b = explicit.comm_latency_layer(&s, 16, 1024, Phase::Prefill, mode);
                assert_eq!(a, b, "{s} {mode:?}");
            }
        }
    }

    #[test]
    fn hot_profile_stretches_ep_but_not_pure_tp() {
        let base = lm();
        let hot = base
            .clone()
            .with_load(ExpertLoadProfile::zipf(256, 8, 1.2, 11));
        let ep = ParallelStrategy::pure_ep(4, 8);
        let hy = ParallelStrategy::mixserve(4, 8);
        let tppp = ParallelStrategy::tp_pp(8, 1); // moe.ep == 1: skew-immune
        for (s, grows) in [(ep, true), (hy, true), (tppp, false)] {
            let a = base.comm_latency_layer(&s, 16, 1024, Phase::Prefill, CommMode::Sync);
            let b = hot.comm_latency_layer(&s, 16, 1024, Phase::Prefill, CommMode::Sync);
            if grows {
                assert!(b > a * 1.05, "{s}: skew must stretch λ ({a} -> {b})");
            } else {
                assert_eq!(a, b, "{s}: pure TP is skew-immune");
            }
        }
    }

    #[test]
    fn pipeline_off_is_bit_for_bit_identical() {
        // the default pipeline path with overlap disabled must reproduce
        // today's latencies exactly (not approximately)
        let plain = lm();
        let off = lm().with_pipeline(PipelineCfg::Off);
        for s in [
            ParallelStrategy::mixserve(4, 8),
            ParallelStrategy::pure_ep(4, 8),
            ParallelStrategy::tp_pp(8, 4),
        ] {
            for mode in [CommMode::Sync, CommMode::FusedAsync] {
                for (b, l) in [(1, 128), (16, 1024)] {
                    for phase in [Phase::Prefill, Phase::Decode] {
                        let a = plain.service_latency(&s, b, l, phase, mode);
                        let o = off.service_latency(&s, b, l, phase, mode);
                        assert_eq!(a.total(), o.total(), "{s} {mode:?} {phase:?} b={b}");
                        assert_eq!(o.overlap, 0.0);
                    }
                }
            }
        }
    }

    #[test]
    fn auto_pipeline_never_slower_and_helps_hybrid_prefill() {
        let plain = lm();
        let auto = lm().with_pipeline(PipelineCfg::Auto);
        let mut helped = false;
        for s in [
            ParallelStrategy::mixserve(4, 8),
            ParallelStrategy::pure_ep(4, 8),
            ParallelStrategy::tp_pp(8, 4),
        ] {
            for (b, l) in [(1, 64), (16, 1024), (16, 4096)] {
                let a = plain.service_latency(&s, b, l, Phase::Prefill, CommMode::FusedAsync);
                let p = auto.service_latency(&s, b, l, Phase::Prefill, CommMode::FusedAsync);
                assert!(p.total() <= a.total() + 1e-15, "{s} b={b} l={l}");
                assert!(p.overlap >= 0.0);
                if s.moe.tp > 1 && s.moe.ep > 1 && p.overlap > 0.0 {
                    helped = true;
                }
            }
        }
        assert!(helped, "chunking must pay somewhere on the hybrid");
    }

    #[test]
    fn one_chunk_reproduces_additive_moe_pricing() {
        let m = lm();
        for s in [ParallelStrategy::mixserve(4, 8), ParallelStrategy::pure_ep(4, 8)] {
            let serial = m.moe_comm_layer(&s, 16, 1024, Phase::Prefill, CommMode::FusedAsync)
                + m.moe_compute_chunk(&s, 16, 1024, Phase::Prefill, 1);
            let piped = m.moe_pipelined_layer(&s, 16, 1024, Phase::Prefill, 1);
            assert!(
                (piped - serial).abs() <= serial * 1e-12,
                "{s}: K=1 {piped} vs additive {serial}"
            );
        }
    }

    #[test]
    fn sync_mode_and_pure_tp_take_no_overlap() {
        let auto = lm().with_pipeline(PipelineCfg::Auto);
        let hybrid = ParallelStrategy::mixserve(4, 8);
        let sync = auto.service_latency(&hybrid, 16, 1024, Phase::Prefill, CommMode::Sync);
        assert_eq!(sync.overlap, 0.0, "Sync schedules have no streams to overlap");
        let tp_only = ParallelStrategy::tp_pp(8, 4);
        let t = auto.service_latency(&tp_only, 16, 1024, Phase::Prefill, CommMode::FusedAsync);
        assert_eq!(t.overlap, 0.0, "no EP dimension, nothing to chunk over");
    }

    #[test]
    fn low_batch_pure_ep_gains_nothing_from_chunking() {
        // launch-dominated: every extra chunk repeats the d−1 α rounds,
        // so the auto search must settle on (effectively) no saving
        let auto = lm().with_pipeline(PipelineCfg::Auto);
        let ep = ParallelStrategy::pure_ep(4, 8);
        let d = auto.service_latency(&ep, 1, 64, Phase::Decode, CommMode::FusedAsync);
        let serial = auto.moe_comm_layer(&ep, 1, 64, Phase::Decode, CommMode::FusedAsync)
            + auto.moe_compute_chunk(&ep, 1, 64, Phase::Decode, 1);
        assert!(
            d.overlap <= serial * 0.02 * auto.model.n_layers as f64,
            "low-batch pure EP must not profit from chunking: {} vs serial {serial}",
            d.overlap
        );
    }

    #[test]
    fn forced_overchunking_can_cost_time() {
        // --chunks honesty: at tiny batch a forced high chunk count pays
        // more launches than it hides, so the saving goes negative
        let forced = lm().with_pipeline(PipelineCfg::Fixed(8));
        let ep = ParallelStrategy::pure_ep(4, 8);
        let d = forced.service_latency(&ep, 1, 64, Phase::Decode, CommMode::FusedAsync);
        assert!(d.overlap < 0.0, "8-way chunking a 1-token decode must cost: {}", d.overlap);
    }

    #[test]
    fn mixed_iteration_with_no_prefill_is_the_decode_pass() {
        // the mixed pricing must degenerate to the decode-phase service
        // latency when no prompt slice rides the iteration
        let m = lm();
        for s in [
            ParallelStrategy::mixserve(4, 8),
            ParallelStrategy::pure_ep(4, 8),
            ParallelStrategy::tp_pp(8, 4),
        ] {
            for mode in [CommMode::Sync, CommMode::FusedAsync] {
                let mix = MixedIter {
                    prefill_reqs: 0,
                    prefill_tokens: 0,
                    prefill_seq: 0,
                    decode_reqs: 16,
                    decode_ctx: 512,
                };
                let a = m.mixed_iteration(&s, &mix, mode).total();
                let b = m.service_latency(&s, 16, 512, Phase::Decode, mode).total();
                assert!(
                    (a - b).abs() <= b * 1e-12,
                    "{s} {mode:?}: mixed-no-prefill {a} != decode pass {b}"
                );
            }
        }
    }

    #[test]
    fn mixed_iteration_subadditive_vs_two_passes() {
        // the fused mixed pass can never cost more than running the
        // prefill group and the decode group as two passes — every cost
        // component (affine comm, saturating-efficiency GEMM, capped HBM
        // stream) is subadditive in the token volume.  This is the
        // mechanism that makes chunked-prefill competitive.
        let m = lm();
        for s in [ParallelStrategy::mixserve(4, 8), ParallelStrategy::pure_ep(4, 8)] {
            for (p_reqs, p_tok, d_reqs) in [(1usize, 256usize, 16usize), (4, 512, 8), (2, 64, 16)]
            {
                let seq = p_tok / p_reqs;
                let mix = MixedIter {
                    prefill_reqs: p_reqs,
                    prefill_tokens: p_tok,
                    prefill_seq: seq,
                    decode_reqs: d_reqs,
                    decode_ctx: 512,
                };
                let fused = m.mixed_iteration(&s, &mix, CommMode::FusedAsync).total();
                let two_pass = m
                    .service_latency(&s, p_reqs, seq, Phase::Prefill, CommMode::FusedAsync)
                    .total()
                    + m.service_latency(&s, d_reqs, 512, Phase::Decode, CommMode::FusedAsync)
                        .total();
                assert!(
                    fused <= two_pass * (1.0 + 1e-9),
                    "{s} p={p_tok} d={d_reqs}: fused {fused} > two passes {two_pass}"
                );
            }
        }
    }

    #[test]
    fn mixed_iteration_monotone_in_prefill_tokens() {
        let m = lm();
        let s = ParallelStrategy::mixserve(4, 8);
        let mk = |p_tok: usize| MixedIter {
            prefill_reqs: 1,
            prefill_tokens: p_tok,
            prefill_seq: p_tok,
            decode_reqs: 16,
            decode_ctx: 512,
        };
        let t64 = m.mixed_iteration(&s, &mk(64), CommMode::FusedAsync).total();
        let t1024 = m.mixed_iteration(&s, &mk(1024), CommMode::FusedAsync).total();
        assert!(t1024 > t64, "more chunk tokens must cost more: {t1024} !> {t64}");
    }

    #[test]
    fn netsim_backend_never_cheaper_than_analytic() {
        let model = MoEModelConfig::deepseek_r1();
        let cl = ClusterConfig::ascend910b();
        let analytic = LatencyModel::new(&model, &cl);
        let contended = LatencyModel::with_cost(&model, &cl, NetSimCost::new(&cl));
        // canonical strategies route intra-node collectives and
        // per-node-aggregated sends: the backends agree exactly there;
        // oversized (inter-node) TP groups share the NIC and must pay.
        for (s, strictly) in [
            (ParallelStrategy::mixserve(4, 8), false),
            (ParallelStrategy::pure_ep(4, 8), false),
            (ParallelStrategy::tp_pp(8, 4), false),
            (ParallelStrategy::tp_pp(32, 1), true),
        ] {
            let a = analytic.comm_latency_layer(&s, 16, 1024, Phase::Prefill, CommMode::Sync);
            let n = contended.comm_latency_layer(&s, 16, 1024, Phase::Prefill, CommMode::Sync);
            assert!(n >= a * (1.0 - 1e-12), "{s}: netsim {n} < analytic {a}");
            if strictly {
                assert!(n > a * 1.5, "{s}: NIC sharing must bite ({n} !>> {a})");
            }
        }
    }
}
