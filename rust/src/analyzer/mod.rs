//! The automatic analyzer (§III-B): offline cost modeling and strategy
//! selection.
//!
//! Inputs: model hyperparameters + cluster/network configuration (and,
//! optionally, profiling observations for calibration).  Output: the
//! optimal [`ParallelStrategy`] plus predicted TTFT / ITL / throughput.

pub mod indicators;
pub mod latency;
pub mod memory;
pub mod profile;
pub mod queueing;
pub mod search;
pub mod tradeoff;

pub use indicators::{Indicators, Workload};
pub use latency::{CommMode, LatencyModel, Phase};
pub use memory::MemoryCheck;
pub use profile::{calibrate, profile_model, Calibration, Observation};
pub use search::{Analyzer, StrategyReport};
pub use tradeoff::{DpEpCase, classify_dp_ep};
