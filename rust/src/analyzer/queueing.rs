//! M/M/1 queueing approximation — §III-B4, Eq. (7).

/// Expected queueing delay W_q for arrival rate `lambda_a` (req/s) and
/// service rate `mu = 1/Δt_svc` (req/s).  Returns `f64::INFINITY` when the
/// stability condition ρ = λ/μ < 1 is violated (saturation).
pub fn mm1_wait(lambda_a: f64, mu: f64) -> f64 {
    if lambda_a <= 0.0 {
        return 0.0;
    }
    if mu <= lambda_a {
        return f64::INFINITY;
    }
    lambda_a / (mu * (mu - lambda_a))
}

/// Evaluation horizon for overloaded systems: the paper benchmarks
/// fixed-length runs, during which an unstable queue grows linearly
/// rather than unboundedly.
pub const EVAL_HORIZON_S: f64 = 60.0;

/// Finite W_q even under overload: M/M/1 when stable; for ρ ≥ 1 the mean
/// wait of arrivals during a horizon T while the backlog grows at rate
/// (λ−μ) — ≈ T·(ρ−1)/(2ρ) · ρ... simplified to the mid-horizon backlog
/// delay plus the near-saturation M/M/1 value for continuity.
pub fn wait_with_overload(lambda_a: f64, mu: f64, horizon: f64) -> f64 {
    if lambda_a <= 0.0 || mu <= 0.0 {
        return if mu <= 0.0 { horizon } else { 0.0 };
    }
    let rho = lambda_a / mu;
    if rho < 0.99 {
        mm1_wait(lambda_a, mu)
    } else {
        // continuity point: W_q at ρ = 0.99, plus linear backlog growth
        let base = mm1_wait(0.99 * mu, mu);
        base + (rho - 0.99).max(0.0) * horizon / 2.0
    }
}

/// Utilization ρ = λ/μ.
pub fn utilization(lambda_a: f64, mu: f64) -> f64 {
    if mu <= 0.0 {
        return f64::INFINITY;
    }
    lambda_a / mu
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn no_arrivals_no_wait() {
        assert_eq!(mm1_wait(0.0, 10.0), 0.0);
    }

    #[test]
    fn saturation_is_infinite() {
        assert!(mm1_wait(10.0, 10.0).is_infinite());
        assert!(mm1_wait(11.0, 10.0).is_infinite());
    }

    #[test]
    fn matches_closed_form() {
        // λ=2, μ=4: Wq = 2/(4·2) = 0.25
        assert!((mm1_wait(2.0, 4.0) - 0.25).abs() < 1e-12);
    }

    #[test]
    fn wait_explodes_near_saturation() {
        let w50 = mm1_wait(5.0, 10.0);
        let w90 = mm1_wait(9.0, 10.0);
        let w99 = mm1_wait(9.9, 10.0);
        assert!(w90 > 5.0 * w50);
        assert!(w99 > 5.0 * w90);
    }

    #[test]
    fn utilization_basic() {
        assert!((utilization(2.0, 8.0) - 0.25).abs() < 1e-12);
    }
}
