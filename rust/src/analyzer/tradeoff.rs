//! DP ↔ EP trade-off — §III-B3, Fig. 6.
//!
//! The Attention block's DP degree and the MoE block's EP degree need not
//! match; the three regimes differ in memory redundancy, throughput, and
//! A2A communicator shape.

use crate::config::ParallelStrategy;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DpEpCase {
    /// d_DP = d_EP — balanced; all devices in one A2A group (Fig. 6a).
    Balanced,
    /// d_DP > d_EP — expert weights replicated d_DP/d_EP times; that many
    /// A2A groups run in parallel, each of d_EP devices (Fig. 6b).
    DpDominant { groups: usize },
    /// d_DP < d_EP — hidden states redundant d_EP/d_DP times; dropping
    /// shrinks the A2A to d_DP groups of d_DP devices (Fig. 6c).
    EpDominant { redundancy: usize },
}

pub fn classify_dp_ep(s: &ParallelStrategy) -> DpEpCase {
    let (dp, ep) = (s.attn.dp, s.moe.ep);
    use std::cmp::Ordering::*;
    match dp.cmp(&ep) {
        Equal => DpEpCase::Balanced,
        Greater => DpEpCase::DpDominant { groups: dp / ep },
        Less => DpEpCase::EpDominant { redundancy: ep / dp },
    }
}

/// Effective A2A (volume multiplier, group degree) per Eq. (5)'s branch:
/// `if d_DP >= d_EP: A2A(b/d_DP·shk, d_EP) else A2A(b/d_EP·shk, d_DP)`.
pub fn effective_a2a(s: &ParallelStrategy) -> (f64, usize) {
    let (dp, ep) = (s.attn.dp as f64, s.moe.ep as f64);
    if dp >= ep {
        (1.0, s.moe.ep)
    } else {
        // hidden-state redundancy dropped: per-group batch b/d_EP
        (dp / ep, s.attn.dp)
    }
}

/// Expert-weight replication factor (memory cost of Fig. 6b).
pub fn weight_replication(s: &ParallelStrategy) -> usize {
    (s.attn.dp / s.moe.ep).max(1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{AttnStrategy, MoeStrategy};

    fn strat(dp: usize, ep: usize) -> ParallelStrategy {
        // keep degrees equal: tp compensates
        let total = 16;
        ParallelStrategy {
            attn: AttnStrategy { tp: total / dp, dp },
            moe: MoeStrategy { tp: total / ep, ep },
            pp: 1,
        }
    }

    #[test]
    fn classification_matches_fig6() {
        assert_eq!(classify_dp_ep(&strat(4, 4)), DpEpCase::Balanced);
        assert_eq!(classify_dp_ep(&strat(8, 4)), DpEpCase::DpDominant { groups: 2 });
        assert_eq!(classify_dp_ep(&strat(2, 4)), DpEpCase::EpDominant { redundancy: 2 });
    }

    #[test]
    fn ep_dominant_shrinks_group_and_volume() {
        let (vol, group) = effective_a2a(&strat(2, 8));
        assert_eq!(group, 2);
        assert!((vol - 0.25).abs() < 1e-12);
    }

    #[test]
    fn balanced_keeps_full_group() {
        let (vol, group) = effective_a2a(&strat(4, 4));
        assert_eq!(group, 4);
        assert_eq!(vol, 1.0);
    }

    #[test]
    fn dp_dominant_replicates_weights() {
        assert_eq!(weight_replication(&strat(8, 2)), 4);
        assert_eq!(weight_replication(&strat(2, 8)), 1);
    }
}
