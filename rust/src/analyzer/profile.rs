//! Offline-stage profiling observations (Fig. 5, §III-A): "MixServe first
//! retrieves the model's hyperparameters and presets prompts with varying
//! batch sizes and sequence lengths to obtain profiling data as
//! observations. [...] Both the observations and theoretical values are
//! then input into the analyzer."
//!
//! On this substrate the observations come from *real PJRT executions* of
//! the tiny AOT model across its compiled shape buckets; calibration fits
//! the effective per-token service rate that the theoretical model's
//! `flops × mfu` term should reproduce, closing the loop between the
//! measured and analytic paths.

use crate::config::{ClusterConfig, MoEModelConfig};
use crate::runtime::model_runner::TinyMoERunner;
use crate::runtime::Engine;
use anyhow::Result;
use std::time::Instant;

/// One profiling observation: a (batch, seq) preset and its measured
/// wall-clock latency.
#[derive(Debug, Clone, Copy)]
pub struct Observation {
    pub batch: usize,
    pub seq: usize,
    /// measured seconds per forward pass
    pub latency: f64,
    /// prefill (full prompt) or decode (single token) measurement
    pub prefill: bool,
}

impl Observation {
    /// Tokens processed by this pass.
    pub fn tokens(&self) -> usize {
        if self.prefill {
            self.batch * self.seq
        } else {
            self.batch
        }
    }
}

/// Profile the tiny model across its compiled buckets (`reps` timed
/// repetitions each, one warmup for compilation).
pub fn profile_model(engine: &Engine, model: &str, reps: usize) -> Result<Vec<Observation>> {
    let runner = TinyMoERunner::load(engine, model)?;
    let info = engine.store.model(model)?.clone();
    let mut out = Vec::new();

    for &(b, s) in &info.prefill_buckets {
        let prompts: Vec<Vec<i32>> =
            (0..b).map(|i| (0..s).map(|j| ((i * 31 + j) % info.vocab) as i32).collect()).collect();
        runner.prefill(engine, &prompts)?; // warmup + compile
        let t0 = Instant::now();
        for _ in 0..reps {
            runner.prefill(engine, &prompts)?;
        }
        out.push(Observation {
            batch: b,
            seq: s,
            latency: t0.elapsed().as_secs_f64() / reps as f64,
            prefill: true,
        });
    }

    for &b in &info.decode_batches {
        let prompts: Vec<Vec<i32>> =
            (0..b).map(|i| (0..16).map(|j| ((i * 7 + j) % info.vocab) as i32).collect()).collect();
        let mut state = runner.prefill(engine, &prompts)?;
        let tokens: Vec<i32> = (0..b as i32).collect();
        // warmup decode
        {
            let mut refs: Vec<&mut _> = state.iter_mut().map(|(_, s)| s).collect();
            runner.decode_step(engine, &tokens, &mut refs)?;
        }
        let t0 = Instant::now();
        for _ in 0..reps {
            let mut refs: Vec<&mut _> = state.iter_mut().map(|(_, s)| s).collect();
            runner.decode_step(engine, &tokens, &mut refs)?;
        }
        out.push(Observation {
            batch: b,
            seq: 1,
            latency: t0.elapsed().as_secs_f64() / reps as f64,
            prefill: false,
        });
    }
    Ok(out)
}

/// Calibration result: the effective compute rate observed on this
/// substrate, and the derate to apply to a cluster description so the
/// theoretical model matches the observations.
#[derive(Debug, Clone, Copy)]
pub struct Calibration {
    /// observed effective FLOP/s (median over observations)
    pub eff_flops: f64,
    /// observations used
    pub n_obs: usize,
}

/// Fit the effective FLOP/s from observations: for each, divide the
/// model's nominal dense FLOPs by the measured latency; take the median
/// (robust to bucket-boundary outliers).
pub fn calibrate(model: &MoEModelConfig, obs: &[Observation]) -> Calibration {
    let mut rates: Vec<f64> = obs
        .iter()
        .filter(|o| o.latency > 0.0)
        .map(|o| {
            let (attn_f, moe_f) = model.flops_per_token_layer(o.seq);
            let flops = o.tokens() as f64 * (attn_f + moe_f) * model.n_layers as f64;
            flops / o.latency
        })
        .collect();
    crate::util::stats::sort_f64(&mut rates);
    let eff = if rates.is_empty() { 0.0 } else { rates[rates.len() / 2] };
    Calibration { eff_flops: eff, n_obs: rates.len() }
}

/// Apply a calibration to a cluster description (observations override
/// the datasheet `flops × mfu` — the analyzer then consumes BOTH, per
/// Fig. 5).
pub fn apply_calibration(cluster: &ClusterConfig, cal: &Calibration) -> ClusterConfig {
    let mut c = cluster.clone();
    if cal.eff_flops > 0.0 {
        c.flops = cal.eff_flops;
        c.mfu = 1.0; // observed rate already includes utilization
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_obs() -> Vec<Observation> {
        vec![
            Observation { batch: 1, seq: 16, latency: 0.010, prefill: true },
            Observation { batch: 4, seq: 32, latency: 0.080, prefill: true },
            Observation { batch: 4, seq: 1, latency: 0.004, prefill: false },
        ]
    }

    #[test]
    fn calibration_is_positive_and_median_based() {
        let m = MoEModelConfig::tiny();
        let cal = calibrate(&m, &fake_obs());
        assert_eq!(cal.n_obs, 3);
        assert!(cal.eff_flops > 0.0);
    }

    #[test]
    fn apply_overrides_datasheet() {
        let c = ClusterConfig::localhost(1, 1);
        let cal = Calibration { eff_flops: 123e9, n_obs: 5 };
        let c2 = apply_calibration(&c, &cal);
        assert_eq!(c2.flops, 123e9);
        assert_eq!(c2.mfu, 1.0);
        // zero-obs calibration is a no-op
        let c3 = apply_calibration(&c, &Calibration { eff_flops: 0.0, n_obs: 0 });
        assert_eq!(c3.flops, c.flops);
    }

    #[test]
    fn observation_token_accounting() {
        let o = Observation { batch: 4, seq: 32, latency: 0.1, prefill: true };
        assert_eq!(o.tokens(), 128);
        let d = Observation { batch: 4, seq: 1, latency: 0.1, prefill: false };
        assert_eq!(d.tokens(), 4);
    }
}
