//! Theoretical performance indicators — §III-B5, Eqs. (9)–(11):
//! TTFT, ITL, and service-level throughput Θ — plus their phase-split
//! form for P/D-disaggregated pools (a prefill pool's server drains
//! prompts at μ = b/Δt_prf; a decode pool's drains generations at
//! μ = b/(L_out·Δt_dec)).

use super::latency::{CommMode, LatencyModel, Phase};
use super::queueing::{wait_with_overload, EVAL_HORIZON_S};
use crate::config::{ParallelStrategy, ServingConfig};
use crate::timing::CommCost;

/// A request-population description (ShareGPT-like averages).
#[derive(Debug, Clone, Copy)]
pub struct Workload {
    /// mean prompt length L_in (tokens)
    pub len_in: usize,
    /// mean generation length L_out (tokens)
    pub len_out: usize,
    /// arrival rate λ_a (requests/s)
    pub rate: f64,
}

impl Workload {
    pub fn sharegpt(rate: f64) -> Self {
        // ShareGPT-V3 published averages: ~230-token prompts, ~200-token
        // responses (see workload::sharegpt for the full distribution).
        Self { len_in: 230, len_out: 200, rate }
    }
}

#[derive(Debug, Clone, Copy)]
pub struct Indicators {
    /// time to first token, seconds (Eq. 9)
    pub ttft: f64,
    /// inter-token latency, seconds (Eq. 10)
    pub itl: f64,
    /// tokens/s at the service level (Eq. 11), per replica set
    pub throughput: f64,
    /// M/M/1 wait (component of TTFT)
    pub queue_wait: f64,
    /// utilization ρ
    pub rho: f64,
}

impl Indicators {
    pub fn is_stable(&self) -> bool {
        self.rho < 1.0 && self.ttft.is_finite()
    }
}

/// Evaluate Eqs. (9)–(11) for a strategy on a workload, under whatever
/// cost backend and load profile the latency model is bound to.
pub fn evaluate<C: CommCost>(
    lm: &LatencyModel<C>,
    strategy: &ParallelStrategy,
    serving: &ServingConfig,
    wl: &Workload,
    mode: CommMode,
) -> Indicators {
    let batch = serving.max_batch;

    // Δt_svc at s = L_in: prefill of the full prompt (Eq. 9)
    let prf = lm
        .service_latency(strategy, batch, wl.len_in, Phase::Prefill, mode)
        .total();
    // Δt_svc at s = 1 with cached context: decode (Eq. 10)
    let ctx = wl.len_in + wl.len_out / 2;
    let dec = lm
        .service_latency(strategy, batch, ctx, Phase::Decode, mode)
        .total();

    // Whole-request service time drives the M/M/1 server: a batch of
    // `batch` requests is served concurrently, so per-request service
    // rate scales with the batch (iteration-level batching).
    let req_service = prf + wl.len_out as f64 * dec;
    let mu = batch as f64 / req_service.max(1e-9);
    // finite even under overload: the paper benchmarks fixed-length runs
    let wq = wait_with_overload(wl.rate, mu, EVAL_HORIZON_S);
    let rho = wl.rate / mu;

    let ttft = wq + prf;
    let itl = dec;
    // Eq. (11): Θ = (L_in + L_out) / (W_q + Δt_prf + L_out·Δt_dec),
    // scaled by the requests a batch serves concurrently; under overload
    // the service pipeline caps tokens/s at μ·(L_in+L_out).
    let theta_demand = (wl.len_in + wl.len_out) as f64
        / (wq + prf + wl.len_out as f64 * dec)
        * batch as f64;
    let theta_capacity = mu * (wl.len_in + wl.len_out) as f64;
    let theta = theta_demand.min(theta_capacity);

    Indicators { ttft, itl, throughput: theta, queue_wait: wq, rho }
}

/// Evaluate one *phase pool* of a P/D-disaggregated deployment.
///
/// The colocated [`evaluate`] drains whole requests; a disaggregated
/// pool only serves its phase, so its M/M/1 server rate and queue wait
/// change while the per-iteration latencies (Eqs. 12–13) stay the same:
///
/// * `Phase::Prefill` — μ = b/Δt_prf; `ttft` = W_q + Δt_prf is the
///   pool's contribution to the fleet TTFT (`queue_wait` = W_q).
/// * `Phase::Decode` — μ = b/(L_out·Δt_dec); `itl` = Δt_dec; the
///   request's wait for a decode slot lands in `queue_wait` (it delays
///   the *second* token, never the first — that already left the
///   prefill pool).
///
/// `throughput` is the pool's sustainable token capacity
/// μ·(L_in + L_out); the fleet planner takes the bottleneck stage's
/// minimum and caps by demand.
pub fn evaluate_phase<C: CommCost>(
    lm: &LatencyModel<C>,
    strategy: &ParallelStrategy,
    serving: &ServingConfig,
    wl: &Workload,
    mode: CommMode,
    phase: Phase,
) -> Indicators {
    let batch = serving.max_batch;
    let prf = lm
        .service_latency(strategy, batch, wl.len_in, Phase::Prefill, mode)
        .total();
    let ctx = wl.len_in + wl.len_out / 2;
    let dec = lm
        .service_latency(strategy, batch, ctx, Phase::Decode, mode)
        .total();

    let service = match phase {
        Phase::Prefill => prf,
        Phase::Decode => wl.len_out as f64 * dec,
    };
    let mu = batch as f64 / service.max(1e-9);
    let wq = wait_with_overload(wl.rate, mu, EVAL_HORIZON_S);
    let rho = wl.rate / mu;
    let ttft = match phase {
        Phase::Prefill => wq + prf,
        // a decode pool never serves a first token; report the service
        // half so the field stays meaningful in rendered tables
        Phase::Decode => prf,
    };
    let theta = mu * (wl.len_in + wl.len_out) as f64;
    Indicators { ttft, itl: dec, throughput: theta, queue_wait: wq, rho }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ClusterConfig, MoEModelConfig};

    fn setup() -> (LatencyModel, ServingConfig) {
        (
            LatencyModel::new(&MoEModelConfig::deepseek_r1(), &ClusterConfig::ascend910b()),
            ServingConfig::default(),
        )
    }

    #[test]
    fn ttft_includes_queue_wait() {
        let (lm, sc) = setup();
        let s = ParallelStrategy::mixserve(4, 8);
        let ind = evaluate(&lm, &s, &sc, &Workload::sharegpt(0.5), CommMode::FusedAsync);
        assert!(ind.is_stable(), "rho = {}", ind.rho);
        assert!(ind.ttft >= ind.queue_wait);
        assert!(ind.ttft > 0.0 && ind.itl > 0.0 && ind.throughput > 0.0);
    }

    #[test]
    fn higher_rate_higher_ttft() {
        let (lm, sc) = setup();
        let s = ParallelStrategy::mixserve(4, 8);
        let lo = evaluate(&lm, &s, &sc, &Workload::sharegpt(2.0), CommMode::FusedAsync);
        let hi = evaluate(&lm, &s, &sc, &Workload::sharegpt(8.0), CommMode::FusedAsync);
        assert!(hi.ttft >= lo.ttft);
    }

    #[test]
    fn fused_dominates_sync_on_all_indicators() {
        let (lm, sc) = setup();
        let s = ParallelStrategy::mixserve(4, 8);
        let wl = Workload::sharegpt(4.0);
        let sync = evaluate(&lm, &s, &sc, &wl, CommMode::Sync);
        let fused = evaluate(&lm, &s, &sc, &wl, CommMode::FusedAsync);
        assert!(fused.ttft <= sync.ttft);
        assert!(fused.itl <= sync.itl);
        assert!(fused.throughput >= sync.throughput);
    }

    #[test]
    fn phase_split_pools_drain_faster_than_colocated() {
        // a pool serving only one phase has a strictly higher service
        // rate than the whole-request server, so its queue wait at the
        // same arrival rate can only shrink
        let (lm, sc) = setup();
        let s = ParallelStrategy::mixserve(4, 8);
        let wl = Workload::sharegpt(4.0);
        let full = evaluate(&lm, &s, &sc, &wl, CommMode::FusedAsync);
        let pre = evaluate_phase(&lm, &s, &sc, &wl, CommMode::FusedAsync, Phase::Prefill);
        let dec = evaluate_phase(&lm, &s, &sc, &wl, CommMode::FusedAsync, Phase::Decode);
        assert!(pre.queue_wait <= full.queue_wait);
        assert!(dec.queue_wait <= full.queue_wait);
        assert!(pre.rho < full.rho && dec.rho < full.rho);
        // the per-iteration latencies are phase-split, not re-derived
        assert_eq!(dec.itl, full.itl);
        assert!(pre.ttft <= full.ttft);
    }

    #[test]
    fn prefill_pool_capacity_exceeds_decode_pool_capacity_per_replica() {
        // one prompt is one iteration; one generation is L_out of them —
        // the asymmetry the planner's pool-size search trades off
        let (lm, sc) = setup();
        let s = ParallelStrategy::mixserve(4, 8);
        let wl = Workload::sharegpt(2.0);
        let pre = evaluate_phase(&lm, &s, &sc, &wl, CommMode::FusedAsync, Phase::Prefill);
        let dec = evaluate_phase(&lm, &s, &sc, &wl, CommMode::FusedAsync, Phase::Decode);
        assert!(
            pre.throughput > dec.throughput,
            "prefill capacity {} must exceed decode capacity {}",
            pre.throughput,
            dec.throughput
        );
    }

    #[test]
    fn itl_millisecond_scale_for_paper_setup() {
        // sanity: DeepSeek-R1 on 32×910B decodes in O(10-300ms)/token
        let (lm, sc) = setup();
        let s = ParallelStrategy::pure_ep(4, 8);
        let ind = evaluate(&lm, &s, &sc, &Workload::sharegpt(2.0), CommMode::Sync);
        assert!(
            (0.005..1.0).contains(&ind.itl),
            "ITL {}s implausible",
            ind.itl
        );
    }
}
