//! Theoretical performance indicators — §III-B5, Eqs. (9)–(11):
//! TTFT, ITL, and service-level throughput Θ — plus their phase-split
//! form for P/D-disaggregated pools (a prefill pool's server drains
//! prompts at μ = b/Δt_prf; a decode pool's drains generations at
//! μ = b/(L_out·Δt_dec)).

use super::latency::{CommMode, LatencyModel, MixedIter, Phase};
use super::queueing::{wait_with_overload, EVAL_HORIZON_S};
use crate::config::{ParallelStrategy, ServingConfig};
use crate::serving::scheduler::SchedPolicy;
use crate::timing::CommCost;

/// A request-population description (ShareGPT-like averages).
#[derive(Debug, Clone, Copy)]
pub struct Workload {
    /// mean prompt length L_in (tokens)
    pub len_in: usize,
    /// mean generation length L_out (tokens)
    pub len_out: usize,
    /// arrival rate λ_a (requests/s)
    pub rate: f64,
}

impl Workload {
    pub fn sharegpt(rate: f64) -> Self {
        // ShareGPT-V3 published averages: ~230-token prompts, ~200-token
        // responses (see workload::sharegpt for the full distribution).
        Self { len_in: 230, len_out: 200, rate }
    }
}

#[derive(Debug, Clone, Copy)]
pub struct Indicators {
    /// time to first token, seconds (Eq. 9)
    pub ttft: f64,
    /// inter-token latency, seconds (Eq. 10)
    pub itl: f64,
    /// tokens/s at the service level (Eq. 11), per replica set
    pub throughput: f64,
    /// M/M/1 wait (component of TTFT)
    pub queue_wait: f64,
    /// utilization ρ
    pub rho: f64,
}

impl Indicators {
    pub fn is_stable(&self) -> bool {
        self.rho < 1.0 && self.ttft.is_finite()
    }
}

/// Evaluate Eqs. (9)–(11) for a strategy on a workload, under whatever
/// cost backend and load profile the latency model is bound to.
pub fn evaluate<C: CommCost>(
    lm: &LatencyModel<C>,
    strategy: &ParallelStrategy,
    serving: &ServingConfig,
    wl: &Workload,
    mode: CommMode,
) -> Indicators {
    let batch = serving.max_batch;

    // Δt_svc at s = L_in: prefill of the full prompt (Eq. 9)
    let prf = lm
        .service_latency(strategy, batch, wl.len_in, Phase::Prefill, mode)
        .total();
    // Δt_svc at s = 1 with cached context: decode (Eq. 10)
    let ctx = wl.len_in + wl.len_out / 2;
    let dec = lm
        .service_latency(strategy, batch, ctx, Phase::Decode, mode)
        .total();

    // Whole-request service time drives the M/M/1 server: a batch of
    // `batch` requests is served concurrently, so per-request service
    // rate scales with the batch (iteration-level batching).
    let req_service = prf + wl.len_out as f64 * dec;
    let mu = batch as f64 / req_service.max(1e-9);
    // finite even under overload: the paper benchmarks fixed-length runs
    let wq = wait_with_overload(wl.rate, mu, EVAL_HORIZON_S);
    let rho = wl.rate / mu;

    let ttft = wq + prf;
    let itl = dec;
    // Eq. (11): Θ = (L_in + L_out) / (W_q + Δt_prf + L_out·Δt_dec),
    // scaled by the requests a batch serves concurrently; under overload
    // the service pipeline caps tokens/s at μ·(L_in+L_out).
    let theta_demand = (wl.len_in + wl.len_out) as f64
        / (wq + prf + wl.len_out as f64 * dec)
        * batch as f64;
    let theta_capacity = mu * (wl.len_in + wl.len_out) as f64;
    let theta = theta_demand.min(theta_capacity);

    Indicators { ttft, itl, throughput: theta, queue_wait: wq, rho }
}

/// Mean end-to-end request latency implied by a set of indicators —
/// the common ranking key of the three-architecture search (colocated
/// FCFS / chunked prefill / disagg all reduce to "how long until the
/// last token", whatever their internal structure).
pub fn request_latency(wl: &Workload, ind: &Indicators) -> f64 {
    ind.ttft + wl.len_out as f64 * ind.itl
}

/// Evaluate a strategy under an explicit iteration scheduler — the
/// *serving-composition-aware* indicators.
///
/// The legacy [`evaluate`] prices the phases in isolation: its ITL is the
/// pure decode pass, even though a colocated FCFS engine's decode tokens
/// share iterations with arriving prompts' prefill passes (the serving
/// sim charges exactly that — `ReplicaSim` records the whole mixed
/// iteration as each token's ITL).  This evaluation makes the scheduler
/// visible:
///
/// * `SchedPolicy::Fcfs` — prefill interference priced into ITL: per
///   wall-clock second the engine spends `λ·Δt_prf/b` seconds prefilling
///   arrivals, so decode iterations stretch by the leftover share
///   (clamped so an overloaded engine prices a finite stall).
/// * `SchedPolicy::Chunked` — the engine runs mixed iterations (Eq. 13
///   on the combined batch): the steady-state prompt-token load per
///   iteration is the demand-limited fixed point capped by the quantum,
///   ITL is the mixed iteration time, and a prompt's prefill spreads
///   over ⌈L_in/quantum⌉ such iterations.
pub fn evaluate_sched<C: CommCost>(
    lm: &LatencyModel<C>,
    strategy: &ParallelStrategy,
    serving: &ServingConfig,
    wl: &Workload,
    mode: CommMode,
    sched: SchedPolicy,
) -> Indicators {
    let batch = serving.max_batch;
    let ctx = wl.len_in + wl.len_out / 2;
    let prf = lm
        .service_latency(strategy, batch, wl.len_in, Phase::Prefill, mode)
        .total();
    let dec = lm
        .service_latency(strategy, batch, ctx, Phase::Decode, mode)
        .total();
    match sched {
        SchedPolicy::Fcfs => {
            // engine share spent prefilling arrivals (per request the
            // full-batch pass amortizes to prf/b); the clamp keeps an
            // overloaded engine's stall finite, like EVAL_HORIZON_S does
            // for the queue
            let rho_p = (wl.rate * prf / batch as f64).min(0.95);
            let itl = dec / (1.0 - rho_p);
            let req_service = prf + wl.len_out as f64 * itl;
            let mu = batch as f64 / req_service.max(1e-9);
            let wq = wait_with_overload(wl.rate, mu, EVAL_HORIZON_S);
            let rho = wl.rate / mu;
            let ttft = wq + prf;
            let theta_demand = (wl.len_in + wl.len_out) as f64 / (wq + req_service).max(1e-9)
                * batch as f64;
            let theta = theta_demand.min(mu * (wl.len_in + wl.len_out) as f64);
            Indicators { ttft, itl, throughput: theta, queue_wait: wq, rho }
        }
        SchedPolicy::Chunked { quantum } => {
            let q = quantum.max(1);
            let iter = |p_tokens: f64| -> f64 {
                let p_tok = p_tokens.round() as usize;
                if p_tok == 0 {
                    return dec;
                }
                let p_reqs = p_tok.div_ceil(wl.len_in.max(1)).max(1);
                lm.mixed_iteration(
                    strategy,
                    &MixedIter {
                        prefill_reqs: p_reqs,
                        prefill_tokens: p_tok,
                        // slices attend over the whole prompt prefix on
                        // average — no discount for being mid-prompt
                        prefill_seq: wl.len_in,
                        decode_reqs: batch,
                        decode_ctx: ctx,
                    },
                    mode,
                )
                .total()
            };
            // steady-state prompt tokens per iteration: the demand-
            // limited fixed point p = min(q, λ·L_in·t(p)), iterated from
            // the quantum (t monotone in p → monotone convergence)
            let mut p = q as f64;
            let mut t_iter = iter(p);
            for _ in 0..6 {
                p = (wl.rate * wl.len_in as f64 * t_iter).min(q as f64);
                t_iter = iter(p);
            }
            // a backlogged engine fills the whole quantum: the prefill
            // stage's capacity and a prompt's own chunk cadence both see
            // saturated iterations
            let t_sat = iter(q as f64);
            let full_chunks = wl.len_in / q;
            let tail = wl.len_in % q;
            let prefill_time =
                full_chunks as f64 * t_sat + if tail > 0 { iter(tail as f64) } else { 0.0 };
            let mu_pre = q as f64 / (wl.len_in as f64 * t_sat).max(1e-9);
            let mu_dec = batch as f64 / (wl.len_out as f64 * t_iter).max(1e-9);
            let mu = mu_pre.min(mu_dec);
            let wq = wait_with_overload(wl.rate, mu, EVAL_HORIZON_S);
            let rho = wl.rate / mu;
            let ttft = wq + prefill_time;
            let itl = t_iter;
            let theta_demand = (wl.len_in + wl.len_out) as f64
                / (wq + prefill_time + wl.len_out as f64 * itl).max(1e-9)
                * batch as f64;
            let theta = theta_demand.min(mu * (wl.len_in + wl.len_out) as f64);
            Indicators { ttft, itl, throughput: theta, queue_wait: wq, rho }
        }
    }
}

/// Evaluate one *phase pool* of a P/D-disaggregated deployment.
///
/// The colocated [`evaluate`] drains whole requests; a disaggregated
/// pool only serves its phase, so its M/M/1 server rate and queue wait
/// change while the per-iteration latencies (Eqs. 12–13) stay the same:
///
/// * `Phase::Prefill` — μ = b/Δt_prf; `ttft` = W_q + Δt_prf is the
///   pool's contribution to the fleet TTFT (`queue_wait` = W_q).
/// * `Phase::Decode` — μ = b/(L_out·Δt_dec); `itl` = Δt_dec; the
///   request's wait for a decode slot lands in `queue_wait` (it delays
///   the *second* token, never the first — that already left the
///   prefill pool).
///
/// `throughput` is the pool's sustainable token capacity
/// μ·(L_in + L_out); the fleet planner takes the bottleneck stage's
/// minimum and caps by demand.
pub fn evaluate_phase<C: CommCost>(
    lm: &LatencyModel<C>,
    strategy: &ParallelStrategy,
    serving: &ServingConfig,
    wl: &Workload,
    mode: CommMode,
    phase: Phase,
) -> Indicators {
    let batch = serving.max_batch;
    let prf = lm
        .service_latency(strategy, batch, wl.len_in, Phase::Prefill, mode)
        .total();
    let ctx = wl.len_in + wl.len_out / 2;
    let dec = lm
        .service_latency(strategy, batch, ctx, Phase::Decode, mode)
        .total();

    let service = match phase {
        Phase::Prefill => prf,
        Phase::Decode => wl.len_out as f64 * dec,
    };
    let mu = batch as f64 / service.max(1e-9);
    let wq = wait_with_overload(wl.rate, mu, EVAL_HORIZON_S);
    let rho = wl.rate / mu;
    let ttft = match phase {
        Phase::Prefill => wq + prf,
        // a decode pool never serves a first token; report the service
        // half so the field stays meaningful in rendered tables
        Phase::Decode => prf,
    };
    let theta = mu * (wl.len_in + wl.len_out) as f64;
    Indicators { ttft, itl: dec, throughput: theta, queue_wait: wq, rho }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ClusterConfig, MoEModelConfig};

    fn setup() -> (LatencyModel, ServingConfig) {
        (
            LatencyModel::new(&MoEModelConfig::deepseek_r1(), &ClusterConfig::ascend910b()),
            ServingConfig::default(),
        )
    }

    #[test]
    fn ttft_includes_queue_wait() {
        let (lm, sc) = setup();
        let s = ParallelStrategy::mixserve(4, 8);
        let ind = evaluate(&lm, &s, &sc, &Workload::sharegpt(0.5), CommMode::FusedAsync);
        assert!(ind.is_stable(), "rho = {}", ind.rho);
        assert!(ind.ttft >= ind.queue_wait);
        assert!(ind.ttft > 0.0 && ind.itl > 0.0 && ind.throughput > 0.0);
    }

    #[test]
    fn higher_rate_higher_ttft() {
        let (lm, sc) = setup();
        let s = ParallelStrategy::mixserve(4, 8);
        let lo = evaluate(&lm, &s, &sc, &Workload::sharegpt(2.0), CommMode::FusedAsync);
        let hi = evaluate(&lm, &s, &sc, &Workload::sharegpt(8.0), CommMode::FusedAsync);
        assert!(hi.ttft >= lo.ttft);
    }

    #[test]
    fn fused_dominates_sync_on_all_indicators() {
        let (lm, sc) = setup();
        let s = ParallelStrategy::mixserve(4, 8);
        let wl = Workload::sharegpt(4.0);
        let sync = evaluate(&lm, &s, &sc, &wl, CommMode::Sync);
        let fused = evaluate(&lm, &s, &sc, &wl, CommMode::FusedAsync);
        assert!(fused.ttft <= sync.ttft);
        assert!(fused.itl <= sync.itl);
        assert!(fused.throughput >= sync.throughput);
    }

    #[test]
    fn phase_split_pools_drain_faster_than_colocated() {
        // a pool serving only one phase has a strictly higher service
        // rate than the whole-request server, so its queue wait at the
        // same arrival rate can only shrink
        let (lm, sc) = setup();
        let s = ParallelStrategy::mixserve(4, 8);
        let wl = Workload::sharegpt(4.0);
        let full = evaluate(&lm, &s, &sc, &wl, CommMode::FusedAsync);
        let pre = evaluate_phase(&lm, &s, &sc, &wl, CommMode::FusedAsync, Phase::Prefill);
        let dec = evaluate_phase(&lm, &s, &sc, &wl, CommMode::FusedAsync, Phase::Decode);
        assert!(pre.queue_wait <= full.queue_wait);
        assert!(dec.queue_wait <= full.queue_wait);
        assert!(pre.rho < full.rho && dec.rho < full.rho);
        // the per-iteration latencies are phase-split, not re-derived
        assert_eq!(dec.itl, full.itl);
        assert!(pre.ttft <= full.ttft);
    }

    #[test]
    fn prefill_pool_capacity_exceeds_decode_pool_capacity_per_replica() {
        // one prompt is one iteration; one generation is L_out of them —
        // the asymmetry the planner's pool-size search trades off
        let (lm, sc) = setup();
        let s = ParallelStrategy::mixserve(4, 8);
        let wl = Workload::sharegpt(2.0);
        let pre = evaluate_phase(&lm, &s, &sc, &wl, CommMode::FusedAsync, Phase::Prefill);
        let dec = evaluate_phase(&lm, &s, &sc, &wl, CommMode::FusedAsync, Phase::Decode);
        assert!(
            pre.throughput > dec.throughput,
            "prefill capacity {} must exceed decode capacity {}",
            pre.throughput,
            dec.throughput
        );
    }

    #[test]
    fn fcfs_sched_prices_prefill_interference_into_itl() {
        let (lm, sc) = setup();
        let s = ParallelStrategy::mixserve(4, 8);
        let wl = Workload::sharegpt(4.0);
        let isolated = evaluate(&lm, &s, &sc, &wl, CommMode::FusedAsync);
        let aware = evaluate_sched(&lm, &s, &sc, &wl, CommMode::FusedAsync, SchedPolicy::Fcfs);
        assert!(
            aware.itl >= isolated.itl,
            "interference can only stretch ITL: {} !>= {}",
            aware.itl,
            isolated.itl
        );
        let (a_prf, i_prf) =
            (aware.ttft - aware.queue_wait, isolated.ttft - isolated.queue_wait);
        assert!(
            (a_prf - i_prf).abs() <= i_prf.abs() * 1e-9,
            "the prefill pass itself is unchanged: {a_prf} vs {i_prf}"
        );
        // interference grows with the arrival rate
        let hot = evaluate_sched(
            &lm, &s, &sc, &Workload::sharegpt(16.0), CommMode::FusedAsync, SchedPolicy::Fcfs,
        );
        assert!(hot.itl >= aware.itl);
    }

    #[test]
    fn chunked_quantum_trades_itl_against_ttft() {
        let (lm, sc) = setup();
        let s = ParallelStrategy::mixserve(4, 8);
        // saturating prompt load: the engine fills whatever quantum it has
        let wl = Workload { len_in: 2048, len_out: 256, rate: 8.0 };
        let small = evaluate_sched(
            &lm, &s, &sc, &wl, CommMode::FusedAsync, SchedPolicy::Chunked { quantum: 128 },
        );
        let large = evaluate_sched(
            &lm, &s, &sc, &wl, CommMode::FusedAsync, SchedPolicy::Chunked { quantum: 2048 },
        );
        assert!(
            small.itl <= large.itl,
            "a smaller quantum must bound the mixed iteration: {} !<= {}",
            small.itl,
            large.itl
        );
        assert!(
            small.ttft - small.queue_wait >= large.ttft - large.queue_wait,
            "slicing a prompt over more iterations stretches its prefill: {} !>= {}",
            small.ttft - small.queue_wait,
            large.ttft - large.queue_wait
        );
    }

    #[test]
    fn chunked_itl_approaches_the_decode_pass_at_light_load() {
        let (lm, sc) = setup();
        let s = ParallelStrategy::mixserve(4, 8);
        let wl = Workload { rate: 0.05, ..Workload::sharegpt(0.05) };
        let ind = evaluate_sched(
            &lm, &s, &sc, &wl, CommMode::FusedAsync, SchedPolicy::Chunked { quantum: 256 },
        );
        let dec = evaluate(&lm, &s, &sc, &wl, CommMode::FusedAsync).itl;
        assert!(ind.itl >= dec, "mixed iterations never beat a pure decode pass");
        assert!(
            ind.itl <= dec * 3.0,
            "at 0.05 req/s the prompt load per iteration is tiny: {} vs {}",
            ind.itl,
            dec
        );
    }

    #[test]
    fn itl_millisecond_scale_for_paper_setup() {
        // sanity: DeepSeek-R1 on 32×910B decodes in O(10-300ms)/token
        let (lm, sc) = setup();
        let s = ParallelStrategy::pure_ep(4, 8);
        let ind = evaluate(&lm, &s, &sc, &Workload::sharegpt(2.0), CommMode::Sync);
        assert!(
            (0.005..1.0).contains(&ind.itl),
            "ITL {}s implausible",
            ind.itl
        );
    }
}
