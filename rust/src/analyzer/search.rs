//! Automatic strategy selection (§III-A offline stage, §III-C2):
//! enumerate the grammar, filter by Eq. (8), score by the theoretical
//! indicators, and return the optimum — "replacing empirical intuition
//! with rigorous analysis".
//!
//! The analyzer is generic over the [`CommCost`] backend (analytic α–β
//! by default, NetSim-backed for contention-aware selection) and carries
//! an [`ExpertLoadProfile`], so the search prices the hot rank's A2A
//! volume under measured gate skew instead of the uniform mean.

use super::indicators::{
    evaluate, evaluate_phase, evaluate_sched, request_latency, Indicators, Workload,
};
use super::latency::{CommMode, LatencyModel, Phase};
use super::memory::{check_memory, MemoryCheck};
use crate::comm::cost::CollectiveCost;
use crate::config::{ClusterConfig, MoEModelConfig, ParallelStrategy, ServingConfig};
use crate::grammar::enumerate_strategies;
use crate::moe::PlacementPolicy;
use crate::pipeline::PipelineCfg;
use crate::serving::scheduler::SchedPolicy;
use crate::timing::{
    kv_handoff_secs, BackendPolicy, CommCost, DispatchBackend, ExpertLoadProfile,
};

/// Seed for measured load profiles built via [`Analyzer::with_load_skew`]
/// (deterministic selection runs).
pub const LOAD_PROFILE_SEED: u64 = 0x10ad;

/// What the analyzer optimizes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Objective {
    /// minimize TTFT (prefill-heavy / interactive)
    MinTtft,
    /// minimize ITL (streaming)
    MinItl,
    /// maximize service throughput Θ (default)
    MaxThroughput,
}

#[derive(Debug, Clone)]
pub struct StrategyReport {
    pub strategy: ParallelStrategy,
    /// the dispatch backend the indicators were priced at (`AllToAll`
    /// under the default [`BackendPolicy::Fixed`] policy; the per-strategy
    /// argmin under [`BackendPolicy::Auto`])
    pub backend: DispatchBackend,
    pub indicators: Indicators,
    pub memory: MemoryCheck,
}

/// The per-phase selection of a P/D-disaggregated deployment: the
/// prefill pool's strategy minimizes TTFT (Eq. 12 priced at s = L_in),
/// the decode pool's minimizes ITL (Eq. 13 at s = 1 over the cached
/// context), searched independently over the same feasible set, plus
/// the CommCost-priced KV handoff that glues the pools together.
#[derive(Debug, Clone)]
pub struct PhasePair {
    pub prefill: StrategyReport,
    pub decode: StrategyReport,
    /// seconds to hand one mean prompt's KV cache across the pools
    pub handoff_secs: f64,
}

/// Scalarize indicators for ranking under an objective (lower is better).
/// Shared by [`Analyzer::rank`] and the fleet planner
/// (`cluster::planner`), which reuses the same ordering one level up.
pub fn objective_key(objective: Objective, ind: &Indicators) -> f64 {
    match objective {
        Objective::MinTtft => ind.ttft,
        Objective::MinItl => ind.itl,
        Objective::MaxThroughput => -ind.throughput,
    }
}

/// The automatic analyzer.
#[derive(Debug, Clone)]
pub struct Analyzer<C: CommCost = CollectiveCost> {
    pub model: MoEModelConfig,
    pub cluster: ClusterConfig,
    pub serving: ServingConfig,
    pub mode: CommMode,
    pub cost: C,
    pub load: ExpertLoadProfile,
    /// chunked micro-batch pipelining priced into every candidate
    /// (`Off` reproduces the additive ranking exactly)
    pub pipeline: PipelineCfg,
    /// which A2A dispatch backends the search may price each candidate
    /// at (`Fixed(AllToAll)` — the default — reproduces the pairwise
    /// ranking bit-for-bit; `Auto` searches the backend jointly with
    /// the strategy)
    pub backend: BackendPolicy,
    /// how experts are laid out across EP ranks: `Static` (the default)
    /// prices the contiguous layout bit-for-bit as before; `Rebalanced`
    /// re-derives each candidate's hot factor from the LPT-replicated
    /// placement at that candidate's EP degree, so "rebalance at this
    /// EP" competes with "drop to a lower EP" on priced merit
    pub placement: PlacementPolicy,
}

impl Analyzer<CollectiveCost> {
    pub fn new(model: &MoEModelConfig, cluster: &ClusterConfig, serving: &ServingConfig) -> Self {
        Self {
            model: model.clone(),
            cluster: cluster.clone(),
            serving: serving.clone(),
            mode: CommMode::FusedAsync,
            cost: CollectiveCost::new(cluster),
            load: ExpertLoadProfile::uniform(model.n_experts),
            pipeline: PipelineCfg::Off,
            backend: BackendPolicy::default(),
            placement: PlacementPolicy::default(),
        }
    }
}

impl<C: CommCost> Analyzer<C> {
    pub fn with_mode(mut self, mode: CommMode) -> Self {
        self.mode = mode;
        self
    }

    /// Swap in a different cost backend (e.g. the NetSim-backed one).
    pub fn with_cost<D: CommCost>(self, cost: D) -> Analyzer<D> {
        Analyzer {
            model: self.model,
            cluster: self.cluster,
            serving: self.serving,
            mode: self.mode,
            cost,
            load: self.load,
            pipeline: self.pipeline,
            backend: self.backend,
            placement: self.placement,
        }
    }

    /// Rank under chunked micro-batch pipelining (overlap-aware
    /// selection): every candidate's MoE block is priced at its best
    /// chunk count (`Auto`) or a forced one (`Fixed`).
    pub fn with_pipeline(mut self, pipeline: PipelineCfg) -> Self {
        self.pipeline = pipeline;
        self
    }

    /// Constrain (or open up) the dispatch-backend dimension of the
    /// search: `Fixed(b)` prices every candidate at backend `b`, `Auto`
    /// picks the per-strategy argmin over [`DispatchBackend::ALL`] under
    /// the same key the entry point ranks by.
    pub fn with_backend(mut self, backend: BackendPolicy) -> Self {
        self.backend = backend;
        self
    }

    /// Choose the expert-placement policy: `Static` leaves every
    /// candidate priced at the contiguous layout (bit-for-bit the
    /// pre-placement ranking); `Rebalanced { budget }` runs the LPT
    /// rebalancer per candidate EP degree and prices the flattened
    /// hot factor instead.
    pub fn with_placement(mut self, placement: PlacementPolicy) -> Self {
        self.placement = placement;
        self
    }

    /// Select under an explicit expert-load profile.
    pub fn with_load(mut self, load: ExpertLoadProfile) -> Self {
        self.load = load;
        self
    }

    /// Select under gate skew measured at Zipf exponent `skew` (0 is the
    /// exact uniform profile: choices reproduce the uniform pricing).
    pub fn with_load_skew(self, skew: f64) -> Self {
        let load = ExpertLoadProfile::zipf(
            self.model.n_experts,
            self.model.top_k,
            skew,
            LOAD_PROFILE_SEED,
        );
        self.with_load(load)
    }

    /// Evaluate one strategy (memory + indicators).  Under a `Fixed`
    /// backend policy the indicators are priced at that backend; under
    /// `Auto` the report carries whichever backend minimizes the mean
    /// end-to-end request latency for this workload shape.
    pub fn report(&self, s: &ParallelStrategy, wl: &Workload) -> StrategyReport {
        let mut lm = LatencyModel::with_cost(&self.model, &self.cluster, self.cost.clone())
            .with_load(self.load.clone())
            .with_pipeline(self.pipeline);
        if !self.placement.is_pinned_default() {
            lm.set_load(self.placement.placed_profile(&self.load, s.moe.ep));
        }
        let memory = check_memory(
            &self.model,
            &self.cluster,
            s,
            self.serving.max_batch,
            self.serving.max_seq,
        );
        let mut best: Option<StrategyReport> = None;
        for backend in self.backend.candidates() {
            lm.set_backend(backend);
            let indicators = evaluate(&lm, s, &self.serving, wl, self.mode);
            let report = StrategyReport { strategy: *s, backend, indicators, memory };
            let better = match &best {
                None => true,
                Some(cur) => {
                    request_latency(wl, &report.indicators)
                        < request_latency(wl, &cur.indicators)
                }
            };
            if better {
                best = Some(report);
            }
        }
        best.expect("BackendPolicy::candidates is never empty")
    }

    /// The candidate pipeline every search entry point shares: enumerate
    /// the grammar, keep full-budget shapes, attach the memory check,
    /// price each (strategy, backend) pair the policy allows with
    /// `indicators`, keep the per-strategy backend argmin by `key`
    /// (strict `<`, so ties resolve to the first candidate — `AllToAll`
    /// — and `Fixed(AllToAll)` reproduces the pairwise ranking
    /// bit-for-bit), drop infeasible/degenerate candidates, and sort
    /// ascending by `key` (`f64::total_cmp` — a NaN indicator ranks
    /// last instead of panicking the whole search).
    fn rank_by(
        &self,
        indicators: impl Fn(&LatencyModel<C>, &ParallelStrategy) -> Indicators,
        key: impl Fn(&StrategyReport) -> f64,
    ) -> Vec<StrategyReport> {
        let mut lm = LatencyModel::with_cost(&self.model, &self.cluster, self.cost.clone())
            .with_load(self.load.clone())
            .with_pipeline(self.pipeline);
        let candidates = self.backend.candidates();
        // One rebalance per distinct EP degree across the whole grammar
        // (the optimizer is deterministic, so the cache is exact).
        let mut placed_cache: std::collections::HashMap<usize, ExpertLoadProfile> =
            std::collections::HashMap::new();
        let mut reports: Vec<StrategyReport> = Vec::new();
        for s in enumerate_strategies(&self.cluster)
            .iter()
            .filter(|s| s.total_devices() == self.cluster.total_devices())
        {
            if !self.placement.is_pinned_default() {
                let placed = placed_cache
                    .entry(s.moe.ep)
                    .or_insert_with(|| self.placement.placed_profile(&self.load, s.moe.ep));
                lm.set_load(placed.clone());
            }
            let memory = check_memory(
                &self.model,
                &self.cluster,
                s,
                self.serving.max_batch,
                self.serving.max_seq,
            );
            if !memory.feasible() {
                continue;
            }
            let mut best: Option<StrategyReport> = None;
            for &backend in &candidates {
                lm.set_backend(backend);
                let report = StrategyReport {
                    strategy: *s,
                    backend,
                    indicators: indicators(&lm, s),
                    memory,
                };
                if !report.indicators.ttft.is_finite() {
                    continue;
                }
                let better = match &best {
                    None => true,
                    Some(cur) => key(&report) < key(cur),
                };
                if better {
                    best = Some(report);
                }
            }
            if let Some(r) = best {
                reports.push(r);
            }
        }
        reports.sort_by(|a, b| key(a).total_cmp(&key(b)));
        reports
    }

    /// All feasible strategies, ranked best-first by `objective`.
    pub fn rank(&self, wl: &Workload, objective: Objective) -> Vec<StrategyReport> {
        self.rank_by(
            |lm, s| evaluate(lm, s, &self.serving, wl, self.mode),
            |r| objective_key(objective, &r.indicators),
        )
    }

    /// The optimum (§III-A: "derive the optimal parallelism strategy").
    pub fn best(&self, wl: &Workload, objective: Objective) -> Option<StrategyReport> {
        self.rank(wl, objective).into_iter().next()
    }

    /// All feasible strategies under an explicit iteration scheduler,
    /// ranked best-first by mean end-to-end request latency — the
    /// three-architecture search's per-pod leg.  The indicators are the
    /// serving-composition-aware ones ([`evaluate_sched`]): FCFS pays its
    /// prefill–decode interference, chunked prefill its quantum-bounded
    /// mixed iterations.
    pub fn rank_sched(&self, wl: &Workload, sched: SchedPolicy) -> Vec<StrategyReport> {
        self.rank_by(
            |lm, s| evaluate_sched(lm, s, &self.serving, wl, self.mode, sched),
            |r| request_latency(wl, &r.indicators),
        )
    }

    /// The scheduler-aware optimum for one pod shape.
    pub fn best_sched(&self, wl: &Workload, sched: SchedPolicy) -> Option<StrategyReport> {
        self.rank_sched(wl, sched).into_iter().next()
    }

    /// All feasible strategies for one phase pool of a disaggregated
    /// deployment, ranked best-first: prefill pools by TTFT, decode
    /// pools by ITL (the per-phase objective is implied by the phase —
    /// exactly the asymmetry of Eqs. (12)–(13)).
    pub fn rank_phase(&self, wl: &Workload, phase: Phase) -> Vec<StrategyReport> {
        let objective = match phase {
            Phase::Prefill => Objective::MinTtft,
            Phase::Decode => Objective::MinItl,
        };
        self.rank_by(
            |lm, s| evaluate_phase(lm, s, &self.serving, wl, self.mode, phase),
            |r| objective_key(objective, &r.indicators),
        )
    }

    /// The per-phase optimum for one pool.
    pub fn best_phase(&self, wl: &Workload, phase: Phase) -> Option<StrategyReport> {
        self.rank_phase(wl, phase).into_iter().next()
    }

    /// The per-phase strategy pair for a P/D-disaggregated deployment on
    /// this cluster shape, with the prefill→decode KV handoff priced
    /// through the bound cost backend (the mean prompt's KV crossing the
    /// inter-pool NIC).
    pub fn best_disagg(&self, wl: &Workload) -> Option<PhasePair> {
        let prefill = self.best_phase(wl, Phase::Prefill)?;
        let decode = self.best_phase(wl, Phase::Decode)?;
        let handoff_secs = kv_handoff_secs(&self.cost, &self.model, wl.len_in);
        Some(PhasePair { prefill, decode, handoff_secs })
    }

    /// The incremental online re-plan behind the elastic controller
    /// (`cluster/controller.rs`): reduce one already-chosen strategy to
    /// its **per-unit-rate utilization** under the workload *shape*.
    /// For a fixed request shape ρ is linear in the arrival rate, so the
    /// controller sizes the active fleet as
    /// `ceil(rho_per_rate · measured_rate / rho_target)` each control
    /// tick without re-running the grammar search in the event loop.
    /// None when the strategy is degenerate under this shape (ρ
    /// non-positive or non-finite).
    pub fn replan(&self, s: &ParallelStrategy, wl: &Workload) -> Option<f64> {
        let rho = self.report(s, wl).indicators.rho;
        let per_rate = rho / wl.rate.max(1e-9);
        (per_rate.is_finite() && per_rate > 0.0).then_some(per_rate)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup(cluster: ClusterConfig) -> Analyzer {
        Analyzer::new(
            &MoEModelConfig::deepseek_r1(),
            &cluster,
            &ServingConfig::default(),
        )
    }

    #[test]
    fn finds_feasible_strategy_for_deepseek_on_910b() {
        let a = setup(ClusterConfig::ascend910b());
        let best = a.best(&Workload::sharegpt(2.0), Objective::MaxThroughput);
        let r = best.expect("must find a feasible strategy");
        assert!(r.memory.feasible());
        assert!(r.indicators.ttft.is_finite());
    }

    #[test]
    fn best_uses_moe_parallelism_not_pure_tp() {
        // pure TP=32 cannot even hold 671B comfortably and its inter-node
        // AR is catastrophic (Fig. 3): the winner must shard experts.
        let a = setup(ClusterConfig::ascend910b());
        let r = a.best(&Workload::sharegpt(2.0), Objective::MaxThroughput).unwrap();
        assert!(r.strategy.moe.ep > 1, "winner {} should use EP", r.strategy);
    }

    #[test]
    fn ranked_list_is_sorted() {
        let a = setup(ClusterConfig::h20());
        let ranked = a.rank(&Workload::sharegpt(2.0), Objective::MinTtft);
        assert!(ranked.len() > 1);
        for w in ranked.windows(2) {
            assert!(w[0].indicators.ttft <= w[1].indicators.ttft);
        }
    }

    #[test]
    fn best_strategy_beats_paper_baselines() {
        // The analyzer's optimum must dominate the Table II baseline
        // configurations it searches over (it includes them).
        let a = setup(ClusterConfig::ascend910b());
        let wl = Workload::sharegpt(4.0);
        let best = a.best(&wl, Objective::MaxThroughput).unwrap();
        for base in [
            ParallelStrategy::tp_pp(8, 4),
            ParallelStrategy::pure_ep(4, 8),
        ] {
            let r = a.report(&base, &wl);
            if r.memory.feasible() && r.indicators.ttft.is_finite() {
                assert!(
                    best.indicators.throughput >= r.indicators.throughput,
                    "{} beat the optimum",
                    base
                );
            }
        }
    }

    #[test]
    fn analyzer_adapts_to_cluster() {
        // §IV-C1: "when cluster bandwidth or node count changes, MixServe
        // re-evaluates the cost model and picks the best feasible tuple".
        let wl = Workload::sharegpt(2.0);
        let a1 = setup(ClusterConfig::ascend910b());
        let mut degraded = ClusterConfig::ascend910b();
        degraded.inter_bw /= 16.0; // starve the NIC
        let a2 = setup(degraded);
        let b1 = a1.best(&wl, Objective::MinTtft).unwrap();
        let b2 = a2.best(&wl, Objective::MinTtft).unwrap();
        // with a starved NIC the optimizer must not pick MORE inter-node
        // traffic than before
        assert!(b2.indicators.ttft >= b1.indicators.ttft * 0.99);
    }

    #[test]
    fn zero_skew_profile_is_identity() {
        let a = setup(ClusterConfig::ascend910b());
        let wl = Workload::sharegpt(4.0);
        let plain = a.best(&wl, Objective::MaxThroughput).unwrap();
        let skewed = a.with_load_skew(0.0).best(&wl, Objective::MaxThroughput).unwrap();
        assert_eq!(plain.strategy, skewed.strategy);
        assert_eq!(plain.indicators.throughput, skewed.indicators.throughput);
    }

    #[test]
    fn overlap_aware_search_never_degrades_any_candidate() {
        // pricing the pipeline (Auto) can only improve each strategy's
        // indicators, and Off reproduces the plain ranking exactly
        let a = setup(ClusterConfig::ascend910b());
        let wl = Workload::sharegpt(4.0);
        let plain = a.clone().rank(&wl, Objective::MaxThroughput);
        let off_analyzer = a.clone().with_pipeline(PipelineCfg::Off);
        let off = off_analyzer.rank(&wl, Objective::MaxThroughput);
        assert_eq!(plain.len(), off.len());
        for (p, o) in plain.iter().zip(&off) {
            assert_eq!(p.strategy, o.strategy);
            assert_eq!(p.indicators.throughput, o.indicators.throughput);
        }
        let auto = a.with_pipeline(PipelineCfg::Auto);
        for p in &plain {
            let r = auto.report(&p.strategy, &wl);
            assert!(
                r.indicators.ttft <= p.indicators.ttft * (1.0 + 1e-12),
                "{}: overlap-aware TTFT {} > additive {}",
                p.strategy,
                r.indicators.ttft,
                p.indicators.ttft
            );
        }
    }

    #[test]
    fn replan_reduces_rho_to_a_rate_linear_coefficient() {
        let a = setup(ClusterConfig::ascend910b());
        let wl = Workload::sharegpt(4.0);
        let s = a.best(&wl, Objective::MaxThroughput).unwrap().strategy;
        let per_rate = a.replan(&s, &wl).expect("a feasible optimum must replan");
        assert!(per_rate > 0.0 && per_rate.is_finite());
        // ρ is linear in the arrival rate for a fixed request shape: the
        // coefficient must not depend on the rate the shape was measured at
        let wl2 = Workload { rate: 8.0, ..wl };
        let per_rate2 = a.replan(&s, &wl2).unwrap();
        assert!(
            (per_rate - per_rate2).abs() < 1e-9 * per_rate.max(per_rate2),
            "per-unit-rate rho drifted with rate: {per_rate} vs {per_rate2}"
        );
        // and it reproduces the full report's utilization when scaled back
        let rho = a.report(&s, &wl).indicators.rho;
        assert!((per_rate * wl.rate - rho).abs() < 1e-12 * rho.abs().max(1.0));
    }

    #[test]
    fn phase_search_optimizes_each_phase_independently() {
        let a = setup(ClusterConfig::ascend910b());
        let wl = Workload::sharegpt(4.0);
        let pair = a.best_disagg(&wl).expect("910B grid must be feasible");
        // each pick is the argmin of its own phase objective over the
        // same feasible set — so it weakly dominates the other pick too
        for r in a.rank_phase(&wl, Phase::Prefill) {
            assert!(pair.prefill.indicators.ttft <= r.indicators.ttft * (1.0 + 1e-12));
        }
        for r in a.rank_phase(&wl, Phase::Decode) {
            assert!(pair.decode.indicators.itl <= r.indicators.itl * (1.0 + 1e-12));
        }
        assert!(pair.decode.indicators.itl <= pair.prefill.indicators.itl * (1.0 + 1e-12));
        assert!(pair.handoff_secs > 0.0, "KV handoff must be priced, not free");
    }

    #[test]
    fn phase_rankings_are_sorted_and_feasible() {
        let a = setup(ClusterConfig::h20());
        let wl = Workload::sharegpt(2.0);
        for phase in [Phase::Prefill, Phase::Decode] {
            let ranked = a.rank_phase(&wl, phase);
            assert!(!ranked.is_empty(), "{phase:?}");
            for r in &ranked {
                assert!(r.memory.feasible());
            }
            for w in ranked.windows(2) {
                match phase {
                    Phase::Prefill => assert!(w[0].indicators.ttft <= w[1].indicators.ttft),
                    Phase::Decode => assert!(w[0].indicators.itl <= w[1].indicators.itl),
                }
            }
        }
    }

    #[test]
    fn sched_rankings_are_sorted_by_request_latency() {
        let a = setup(ClusterConfig::ascend910b());
        let wl = Workload::sharegpt(4.0);
        for sched in [SchedPolicy::Fcfs, SchedPolicy::Chunked { quantum: 256 }] {
            let ranked = a.rank_sched(&wl, sched);
            assert!(!ranked.is_empty(), "{sched:?}");
            for r in &ranked {
                assert!(r.memory.feasible());
            }
            for w in ranked.windows(2) {
                assert!(
                    request_latency(&wl, &w[0].indicators)
                        <= request_latency(&wl, &w[1].indicators),
                    "{sched:?}: ranking must ascend"
                );
            }
        }
    }

    #[test]
    fn fcfs_sched_optimum_never_beats_the_isolated_itl() {
        // the composition-aware FCFS pricing only ADDS interference, so
        // its best request latency cannot undercut the phase-isolated
        // evaluation of the same strategy
        let a = setup(ClusterConfig::ascend910b());
        let wl = Workload::sharegpt(4.0);
        let best = a.best_sched(&wl, SchedPolicy::Fcfs).expect("feasible");
        let isolated = a.report(&best.strategy, &wl);
        assert!(best.indicators.itl >= isolated.indicators.itl * (1.0 - 1e-12));
    }

    #[test]
    fn default_policy_prices_every_report_at_the_pairwise_backend() {
        // the default Fixed(AllToAll) policy has exactly one candidate,
        // so every report carries the pinned backend and report() agrees
        // with the ranked entry for the same strategy bit-for-bit
        let a = setup(ClusterConfig::ascend910b());
        let wl = Workload::sharegpt(4.0);
        let ranked = a.rank(&wl, Objective::MaxThroughput);
        assert!(!ranked.is_empty());
        for r in &ranked {
            assert_eq!(r.backend, DispatchBackend::AllToAll);
            let again = a.report(&r.strategy, &wl);
            assert_eq!(again.backend, DispatchBackend::AllToAll);
            assert_eq!(again.indicators.throughput, r.indicators.throughput);
            assert_eq!(again.indicators.ttft, r.indicators.ttft);
        }
    }

    #[test]
    fn auto_backend_never_degrades_and_strictly_improves_somewhere() {
        // Auto takes the per-strategy argmin over a candidate set that
        // contains AllToAll, so no strategy's key can degrade — and on
        // this grid at least one candidate must strictly prefer a fused
        // or masked backend (the whole point of searching the dimension)
        let a = setup(ClusterConfig::h20());
        let wl = Workload::sharegpt(4.0);
        let plain = a.clone().rank(&wl, Objective::MaxThroughput);
        let auto = a.with_backend(BackendPolicy::Auto);
        let opened = auto.rank(&wl, Objective::MaxThroughput);
        // opening the backend dimension can only widen the feasible set
        // (a strategy saturated under A2A may become finite under a
        // cheaper exchange), never shrink it
        assert!(opened.len() >= plain.len());
        let mut improved = false;
        for p in &plain {
            let q = opened
                .iter()
                .find(|q| q.strategy == p.strategy)
                .expect("every A2A-feasible strategy stays feasible under Auto");
            assert!(
                q.indicators.throughput >= p.indicators.throughput,
                "{}: Auto throughput {} < pinned {}",
                p.strategy,
                q.indicators.throughput,
                p.indicators.throughput
            );
            if q.backend != DispatchBackend::AllToAll
                && q.indicators.throughput > p.indicators.throughput
            {
                improved = true;
            }
        }
        assert!(
            improved,
            "Auto never strictly improved any candidate on the H20 grid"
        );
    }

    #[test]
    fn auto_backend_diverges_across_phases_on_some_grid() {
        // prefill pools move whole prompts (wire-bound: the
        // high-throughput trade wins) while decode pools move one token
        // per step (launch-bound: low-latency wins) — on at least one
        // paper grid the per-phase searches must disagree on the backend
        let wl = Workload::sharegpt(4.0);
        let diverged = [ClusterConfig::h20(), ClusterConfig::ascend910b()]
            .into_iter()
            .any(|cluster| {
                setup(cluster)
                    .with_backend(BackendPolicy::Auto)
                    .best_disagg(&wl)
                    .map(|pair| pair.prefill.backend != pair.decode.backend)
                    .unwrap_or(false)
            });
        assert!(diverged, "no grid split the backend across P/D phases");
    }

    #[test]
    fn fixed_non_default_backend_is_honored_everywhere() {
        let a = setup(ClusterConfig::ascend910b())
            .with_backend(BackendPolicy::Fixed(DispatchBackend::FusedLowLatency));
        let wl = Workload::sharegpt(4.0);
        for r in a.rank(&wl, Objective::MinItl) {
            assert_eq!(r.backend, DispatchBackend::FusedLowLatency);
        }
        let s = a.best(&wl, Objective::MinItl).unwrap().strategy;
        assert_eq!(a.report(&s, &wl).backend, DispatchBackend::FusedLowLatency);
    }

    #[test]
    fn static_placement_policy_is_the_identity() {
        // the explicit Static knob must not perturb a single bit of the
        // skew-aware ranking
        let a = setup(ClusterConfig::ascend910b()).with_load_skew(0.8);
        let wl = Workload::sharegpt(4.0);
        let plain = a.clone().rank(&wl, Objective::MaxThroughput);
        let pinned = a.with_placement(PlacementPolicy::Static).rank(&wl, Objective::MaxThroughput);
        assert_eq!(plain.len(), pinned.len());
        for (p, q) in plain.iter().zip(&pinned) {
            assert_eq!(p.strategy, q.strategy);
            assert_eq!(p.indicators.ttft.to_bits(), q.indicators.ttft.to_bits());
            assert_eq!(p.indicators.throughput.to_bits(), q.indicators.throughput.to_bits());
        }
    }

    #[test]
    fn rebalanced_placement_never_degrades_any_candidate() {
        // the rebalancer's hot factor is ≤ the static one at every EP
        // degree (contiguous fallback), and latency is monotone in the
        // hot factor — so no candidate's throughput may drop, and on a
        // heavily skewed profile the high-EP candidates must strictly
        // improve
        let a = setup(ClusterConfig::ascend910b()).with_load_skew(1.2);
        let wl = Workload::sharegpt(4.0);
        let plain = a.clone().rank(&wl, Objective::MaxThroughput);
        let opened = a
            .with_placement(PlacementPolicy::Rebalanced { budget: 2 })
            .rank(&wl, Objective::MaxThroughput);
        // flattening λ can only widen the feasible set, never shrink it
        assert!(opened.len() >= plain.len());
        let mut improved = false;
        for p in &plain {
            let q = opened
                .iter()
                .find(|q| q.strategy == p.strategy)
                .expect("every static-feasible strategy stays feasible rebalanced");
            assert!(
                q.indicators.throughput >= p.indicators.throughput * (1.0 - 1e-12),
                "{}: rebalanced throughput {} < static {}",
                p.strategy,
                q.indicators.throughput,
                p.indicators.throughput
            );
            if p.strategy.moe.ep > 1 && q.indicators.throughput > p.indicators.throughput {
                improved = true;
            }
        }
        assert!(improved, "rebalancing never improved any EP candidate at zipf 1.2");
    }

    #[test]
    fn netsim_backend_searches_too() {
        use crate::timing::NetSimCost;
        let cluster = ClusterConfig::h20();
        let a = Analyzer::new(
            &MoEModelConfig::qwen3_235b(),
            &cluster,
            &ServingConfig::default(),
        )
        .with_cost(NetSimCost::new(&cluster));
        let r = a.best(&Workload::sharegpt(2.0), Objective::MaxThroughput);
        assert!(r.expect("netsim-backed search must succeed").memory.feasible());
    }
}
