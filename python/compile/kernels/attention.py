"""L1 Pallas kernel: decode-phase attention over the KV cache.

One grid step per (batch, head).  The query row lives in VMEM; the K/V
cache for that (b, head) is streamed through VMEM in seq chunks with an
online-softmax accumulator carried by a fori_loop *inside* the kernel —
flash-attention restructured for a scratchpad (no shared-memory tiles, no
cross-step semaphores; the HBM<->VMEM schedule is the BlockSpec plus the
chunk loop).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_CHUNK_S = 128


def _decode_attn_kernel(chunk_s: int, q_ref, k_ref, v_ref, o_ref):
    q = q_ref[0]              # [1, hd] query row for this (b, head)
    hd = q.shape[-1]
    ks = k_ref[0, 0]          # [s, hd] cache staged for this (b, head)
    vs = v_ref[0, 0]
    s = ks.shape[0]
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))

    n_chunks = pl.cdiv(s, chunk_s)

    def body(c, carry):
        m_prev, l_prev, acc = carry
        kc = jax.lax.dynamic_slice_in_dim(ks, c * chunk_s, chunk_s, 0)
        vc = jax.lax.dynamic_slice_in_dim(vs, c * chunk_s, chunk_s, 0)
        logits = (q @ kc.T) * scale                  # [1, chunk]
        m_cur = jnp.max(logits, axis=-1)             # [1]
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(logits - m_new[:, None])         # [1, chunk]
        alpha = jnp.exp(m_prev - m_new)              # [1]
        l_new = alpha * l_prev + jnp.sum(p, axis=-1)
        acc = acc * alpha[:, None] + p @ vc          # [1, hd]
        return m_new, l_new, acc

    m0 = jnp.full((1,), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((1,), jnp.float32)
    acc0 = jnp.zeros((1, hd), jnp.float32)
    _, l, acc = jax.lax.fori_loop(0, n_chunks, body, (m0, l0, acc0))
    o_ref[0] = (acc / l[:, None]).astype(o_ref.dtype)


def _decode_attn_masked_kernel(chunk_s: int, len_ref, q_ref, k_ref, v_ref,
                               o_ref):
    """Like _decode_attn_kernel but only the first `valid_len` cache rows
    participate (the rest are padding in a max-seq-length cache)."""
    q = q_ref[0]              # [1, hd]
    hd = q.shape[-1]
    ks = k_ref[0, 0]          # [smax, hd]
    vs = v_ref[0, 0]
    s = ks.shape[0]
    valid = len_ref[0, 0]     # scalar i32
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    n_chunks = pl.cdiv(s, chunk_s)

    def body(c, carry):
        m_prev, l_prev, acc = carry
        kc = jax.lax.dynamic_slice_in_dim(ks, c * chunk_s, chunk_s, 0)
        vc = jax.lax.dynamic_slice_in_dim(vs, c * chunk_s, chunk_s, 0)
        idx = c * chunk_s + jax.lax.iota(jnp.int32, chunk_s)
        logits = (q @ kc.T) * scale                  # [1, chunk]
        logits = jnp.where(idx[None, :] < valid, logits, -jnp.inf)
        m_cur = jnp.max(logits, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.where(jnp.isfinite(logits), jnp.exp(logits - m_new[:, None]),
                      0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=-1)
        acc = acc * alpha[:, None] + p @ vc
        return m_new, l_new, acc

    m0 = jnp.full((1,), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((1,), jnp.float32)
    acc0 = jnp.zeros((1, hd), jnp.float32)
    _, l, acc = jax.lax.fori_loop(0, n_chunks, body, (m0, l0, acc0))
    o_ref[0] = (acc / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk_s",))
def decode_attention_masked(q, k, v, valid_len, chunk_s=DEFAULT_CHUNK_S):
    """Decode attention over a padded cache: only rows < valid_len attend.

    q: [b, nh, hd]; k/v: [b, smax, nh, hd]; valid_len: scalar i32.
    """
    b, nh, hd = q.shape
    s = k.shape[1]
    chunk_s = min(chunk_s, s)
    if s % chunk_s != 0:
        chunk_s = s
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    vlen = jnp.asarray(valid_len, jnp.int32).reshape(1, 1)
    grid = (b, nh)
    return pl.pallas_call(
        functools.partial(_decode_attn_masked_kernel, chunk_s),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda bi, hi: (0, 0)),
            pl.BlockSpec((1, 1, hd), lambda bi, hi: (bi, hi, 0)),
            pl.BlockSpec((1, 1, s, hd), lambda bi, hi: (bi, hi, 0, 0)),
            pl.BlockSpec((1, 1, s, hd), lambda bi, hi: (bi, hi, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, hd), lambda bi, hi: (bi, hi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, nh, hd), q.dtype),
        interpret=True,
        name="decode_attention_masked",
    )(vlen, q, kt, vt)


@functools.partial(jax.jit, static_argnames=("chunk_s",))
def decode_attention(q, k, v, chunk_s=DEFAULT_CHUNK_S):
    """Decode attention: q: [b, nh, hd]; k/v: [b, s, nh, hd] -> [b, nh, hd].

    All cached positions are visible (decode step attends to full prefix).
    """
    b, nh, hd = q.shape
    s = k.shape[1]
    chunk_s = min(chunk_s, s)
    if s % chunk_s != 0:
        chunk_s = s  # fall back to one chunk: avoids clamped-slice overlap
    # [b, nh, hd] -> grid (b, nh); K/V staged as [b, nh, s, hd]
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    grid = (b, nh)
    out = pl.pallas_call(
        functools.partial(_decode_attn_kernel, chunk_s),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, hd), lambda bi, hi: (bi, hi, 0)),
            pl.BlockSpec((1, 1, s, hd), lambda bi, hi: (bi, hi, 0, 0)),
            pl.BlockSpec((1, 1, s, hd), lambda bi, hi: (bi, hi, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, hd), lambda bi, hi: (bi, hi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, nh, hd), q.dtype),
        interpret=True,
        name="decode_attention",
    )(q, kt, vt)
    return out
