"""L1 Pallas kernel: MoE router (softmax gate + iterative top-k).

Token-choice routing: each token picks its top-k experts by softmax
probability.  Re-thought for a scratchpad memory system: the whole
(token-block x E) probability tile lives in VMEM and top-k is an
iterative max-and-mask loop (k is tiny: 2..8), fully vectorized over the
token block on the VPU — no HBM gather/scatter, no sort network.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_BLOCK_T = 128


def _gate_kernel(k: int, x_ref, wr_ref, w_out_ref, i_out_ref):
    x = x_ref[...]                                    # [bt, h]
    wr = wr_ref[...]                                  # [h, E]
    logits = jnp.dot(x, wr, preferred_element_type=jnp.float32)  # [bt, E]
    m = jnp.max(logits, axis=-1, keepdims=True)
    p = jnp.exp(logits - m)
    probs = p / jnp.sum(p, axis=-1, keepdims=True)    # softmax, [bt, E]

    e = probs.shape[-1]
    eye = jax.lax.broadcasted_iota(jnp.int32, probs.shape, 1)  # [bt, E]

    def body(j, carry):
        masked, ws, idxs = carry
        top = jnp.max(masked, axis=-1)                          # [bt]
        arg = jnp.argmax(masked, axis=-1).astype(jnp.int32)     # [bt]
        ws = ws.at[:, j].set(top)
        idxs = idxs.at[:, j].set(arg)
        masked = jnp.where(eye == arg[:, None], -jnp.inf, masked)
        return masked, ws, idxs

    bt = probs.shape[0]
    ws0 = jnp.zeros((bt, k), jnp.float32)
    idx0 = jnp.zeros((bt, k), jnp.int32)
    _, ws, idxs = jax.lax.fori_loop(0, k, body, (probs, ws0, idx0))
    ws = ws / jnp.sum(ws, axis=-1, keepdims=True)     # renormalize top-k
    w_out_ref[...] = ws.astype(w_out_ref.dtype)
    i_out_ref[...] = idxs


@functools.partial(jax.jit, static_argnames=("k", "block_t"))
def topk_gate(x, w_router, k, block_t=DEFAULT_BLOCK_T):
    """Router: softmax(x @ Wr) -> renormalized top-k weights + indices.

    x: [t, h]; w_router: [h, E] -> (weights [t, k] f32, idx [t, k] i32)
    """
    t, h = x.shape
    e = w_router.shape[-1]
    block_t = min(block_t, t)
    if t % block_t != 0:
        raise ValueError(f"tokens {t} not divisible by block_t {block_t}")
    grid = (t // block_t,)
    return pl.pallas_call(
        functools.partial(_gate_kernel, k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_t, h), lambda ti: (ti, 0)),
            pl.BlockSpec((h, e), lambda ti: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_t, k), lambda ti: (ti, 0)),
            pl.BlockSpec((block_t, k), lambda ti: (ti, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((t, k), x.dtype),
            jax.ShapeDtypeStruct((t, k), jnp.int32),
        ],
        interpret=True,
        name="topk_gate",
    )(x, w_router)
