"""L1 Pallas kernel: grouped (per-expert) SwiGLU MLP.

The paper's compute hot-spot is the expert FFN of the MoE block. On
GPU/NPU this is a grouped GEMM over capacity-packed token buffers; here it
is re-thought for a TPU-like memory system (see DESIGN.md
§Hardware-Adaptation):

  * grid = (E, C // block_t): one step per (expert, token-block);
  * BlockSpec index maps stage the token block and exactly that expert's
    W_gate/W_up/W_down slices HBM->VMEM — the analogue of per-threadblock
    expert routing in the CUDA grouped-GEMM;
  * the MXU consumes (block_t x h)·(h x f) matmuls; the SwiGLU elementwise
    runs on the VPU in VMEM without a round-trip to HBM.

interpret=True everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls, so the kernel lowers to plain HLO for both pytest and the
AOT artifacts.  Real-TPU VMEM/MXU estimates live in DESIGN.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_BLOCK_T = 64


def _swiglu_kernel(x_ref, wg_ref, wu_ref, wd_ref, o_ref):
    """One (expert, token-block) step: o = (silu(x@wg) * (x@wu)) @ wd."""
    x = x_ref[0]            # [block_t, h]   (VMEM; leading expert dim squeezed)
    wg = wg_ref[0]          # [h, f]
    wu = wu_ref[0]
    wd = wd_ref[0]          # [f, h]
    g = jnp.dot(x, wg, preferred_element_type=jnp.float32)
    u = jnp.dot(x, wu, preferred_element_type=jnp.float32)
    a = (g * jax.nn.sigmoid(g)) * u
    o_ref[0] = jnp.dot(a, wd, preferred_element_type=jnp.float32).astype(
        o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_t",))
def grouped_expert_mlp(xs, w_gate, w_up, w_down, block_t=DEFAULT_BLOCK_T):
    """Grouped SwiGLU expert MLP.

    xs: [E, C, h] capacity-packed tokens (C tokens per expert);
    w_gate/w_up: [E, h, f]; w_down: [E, f, h]  ->  [E, C, h].
    """
    e, c, h = xs.shape
    f = w_gate.shape[-1]
    block_t = min(block_t, c)
    if c % block_t != 0:
        raise ValueError(f"capacity {c} not divisible by block_t {block_t}")
    grid = (e, c // block_t)
    return pl.pallas_call(
        _swiglu_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_t, h), lambda ei, ti: (ei, ti, 0)),
            pl.BlockSpec((1, h, f), lambda ei, ti: (ei, 0, 0)),
            pl.BlockSpec((1, h, f), lambda ei, ti: (ei, 0, 0)),
            pl.BlockSpec((1, f, h), lambda ei, ti: (ei, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_t, h), lambda ei, ti: (ei, ti, 0)),
        out_shape=jax.ShapeDtypeStruct((e, c, h), xs.dtype),
        interpret=True,
        name="grouped_expert_mlp",
    )(xs, w_gate, w_up, w_down)


def expert_mlp(x, w_gate, w_up, w_down, block_t=DEFAULT_BLOCK_T):
    """Single-expert SwiGLU MLP via the grouped kernel (E=1).

    x: [t, h]; w_gate/w_up: [h, f]; w_down: [f, h] -> [t, h]
    """
    y = grouped_expert_mlp(x[None], w_gate[None], w_up[None], w_down[None],
                           block_t=min(block_t, x.shape[0]))
    return y[0]


def vmem_bytes_per_step(block_t, h, f, dtype_bytes=4):
    """VMEM footprint estimate of one grid step (for DESIGN.md §Perf).

    x block + 3 weight slices + activations (g, u, a) + output block.
    """
    return dtype_bytes * (
        block_t * h          # x
        + 2 * h * f          # wg, wu
        + f * h              # wd
        + 3 * block_t * f    # g, u, a
        + block_t * h        # o
    )


def mxu_flops_per_step(block_t, h, f):
    """MACs*2 of one grid step: three GEMMs."""
    return 2 * block_t * f * (2 * h + h) + 0  # x@wg, x@wu: t*h*f each; a@wd: t*f*h
