"""Pure-jnp oracles for the Pallas kernels (L1 correctness references).

Every kernel in this package has an exact functional twin here; pytest +
hypothesis sweep shapes/dtypes and assert_allclose kernel-vs-ref.
"""

import jax
import jax.numpy as jnp


def silu(x):
    return x * jax.nn.sigmoid(x)


def expert_mlp_ref(x, w_gate, w_up, w_down):
    """SwiGLU expert MLP: (silu(x @ Wg) * (x @ Wu)) @ Wd.

    x: [t, h]; w_gate/w_up: [h, f]; w_down: [f, h] -> [t, h]
    """
    return (silu(x @ w_gate) * (x @ w_up)) @ w_down


def grouped_expert_mlp_ref(xs, w_gate, w_up, w_down):
    """Grouped (per-expert) SwiGLU MLP over capacity-packed tokens.

    xs: [E, C, h]; w_gate/w_up: [E, h, f]; w_down: [E, f, h] -> [E, C, h]
    """
    return jax.vmap(expert_mlp_ref)(xs, w_gate, w_up, w_down)


def topk_gate_ref(x, w_router, k):
    """Router: softmax(x @ Wr) then top-k.

    x: [t, h]; w_router: [h, E] -> (weights [t, k] renormalized, idx [t, k] i32)

    Implemented as iterative max-and-mask (not jax.lax.top_k): identical
    numerics and tie-breaking, and it lowers to plain HLO — lax.top_k
    emits a `topk(..., largest=true)` op that xla_extension 0.5.1's text
    parser rejects (see /opt/xla-example/README.md gotchas).
    """
    logits = x @ w_router
    probs = jax.nn.softmax(logits, axis=-1)
    t = probs.shape[0]
    eye = jax.lax.broadcasted_iota(jnp.int32, probs.shape, 1)

    def body(j, carry):
        masked, ws, idxs = carry
        top = jnp.max(masked, axis=-1)
        arg = jnp.argmax(masked, axis=-1).astype(jnp.int32)
        ws = ws.at[:, j].set(top)
        idxs = idxs.at[:, j].set(arg)
        masked = jnp.where(eye == arg[:, None], -jnp.inf, masked)
        return masked, ws, idxs

    ws0 = jnp.zeros((t, k), probs.dtype)
    idx0 = jnp.zeros((t, k), jnp.int32)
    _, top_w, top_i = jax.lax.fori_loop(0, k, body, (probs, ws0, idx0))
    top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)
    return top_w, top_i


def decode_attention_ref(q, k, v, scale=None):
    """Single-step decode attention (no mask: all cached positions visible).

    q: [b, nh, hd]; k/v: [b, s, nh, hd] -> [b, nh, hd]
    """
    hd = q.shape[-1]
    if scale is None:
        scale = (1.0 / jnp.sqrt(hd)).astype(q.dtype)
    # [b, nh, s]
    logits = jnp.einsum("bnd,bsnd->bns", q, k) * scale
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bns,bsnd->bnd", probs, v)


def moe_block_ref(x, w_router, w_gate, w_up, w_down, k,
                  w_shared_gate=None, w_shared_up=None, w_shared_down=None):
    """Dense reference of a full MoE block (token-choice top-k routing).

    x: [t, h]; w_router: [h, E]; w_gate/w_up: [E, h, f]; w_down: [E, f, h]
    Optional shared expert (DeepSeek-style) weights: [h, f], [h, f], [f, h].
    Computed densely: every expert processes every token, then combined by
    the gate weights; mathematically identical to dispatch/combine.
    """
    gate_w, gate_i = topk_gate_ref(x, w_router, k)           # [t,k], [t,k]
    e = w_gate.shape[0]
    # [t, E] combine matrix from top-k (scatter of gate weights)
    combine = jnp.zeros((x.shape[0], e), x.dtype)
    combine = combine.at[jnp.arange(x.shape[0])[:, None], gate_i].set(gate_w)
    all_out = jax.vmap(lambda wg, wu, wd: expert_mlp_ref(x, wg, wu, wd))(
        w_gate, w_up, w_down)                                 # [E, t, h]
    y = jnp.einsum("te,eth->th", combine, all_out)
    if w_shared_gate is not None:
        y = y + expert_mlp_ref(x, w_shared_gate, w_shared_up, w_shared_down)
    return y
