"""L2: tiny MoE decoder in JAX (calls the L1 Pallas kernels).

This is the *numeric* half of the reproduction: a real (small) MoE
transformer whose forward pass exercises the exact sharded algebra that
MixServe's hybrid TP-EP partitioner and fused AR-A2A schedules move over
the wire — TP column/row slices of attention, expert shards, top-k
dispatch/combine.  The 671B/235B paper models appear only in the L3
*analytical* path (hyperparameters feeding the cost model).

Everything here is build-time Python: `aot.py` lowers these functions to
HLO text once; the Rust runtime executes the artifacts.
"""

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import ref
from .kernels.attention import decode_attention_masked
from .kernels.moe_mlp import grouped_expert_mlp
from .kernels.topk_gate import topk_gate


@dataclasses.dataclass(frozen=True)
class TinyMoEConfig:
    """Hyperparameters of the numeric-path MoE model."""

    vocab: int = 512
    hidden: int = 128
    n_heads: int = 4
    head_dim: int = 32
    expert_inter: int = 256   # f: per-expert FFN intermediate dim
    n_experts: int = 8        # E routed experts
    top_k: int = 2
    shared_expert: bool = True  # DeepSeek-style shared expert
    n_layers: int = 2
    max_seq: int = 256

    @property
    def qkv_dim(self):
        return self.n_heads * self.head_dim

    def param_names(self):
        """Deterministic flat parameter ordering (shared with aot manifest
        and the Rust weight loader)."""
        names = ["embed"]
        for i in range(self.n_layers):
            names += [f"l{i}.{n}" for n in
                      ["ln1", "wq", "wk", "wv", "wo", "ln2", "router",
                       "wg", "wu", "wd", "sg", "su", "sd"]]
        names.append("ln_f")
        return names

    def param_shapes(self):
        c = self
        per_layer = {
            "ln1": (c.hidden,),
            "wq": (c.hidden, c.qkv_dim),
            "wk": (c.hidden, c.qkv_dim),
            "wv": (c.hidden, c.qkv_dim),
            "wo": (c.qkv_dim, c.hidden),
            "ln2": (c.hidden,),
            "router": (c.hidden, c.n_experts),
            "wg": (c.n_experts, c.hidden, c.expert_inter),
            "wu": (c.n_experts, c.hidden, c.expert_inter),
            "wd": (c.n_experts, c.expert_inter, c.hidden),
            "sg": (c.hidden, c.expert_inter),
            "su": (c.hidden, c.expert_inter),
            "sd": (c.expert_inter, c.hidden),
        }
        shapes = {"embed": (c.vocab, c.hidden)}
        for i in range(c.n_layers):
            for n, s in per_layer.items():
                shapes[f"l{i}.{n}"] = s
        shapes["ln_f"] = (c.hidden,)
        return shapes

    def n_params(self):
        return sum(int(np.prod(s)) for s in self.param_shapes().values())


TINY = TinyMoEConfig()
# ~110M parameters: the end-to-end example's "small real model".
SMALL = TinyMoEConfig(vocab=8192, hidden=512, n_heads=8, head_dim=64,
                      expert_inter=1024, n_experts=16, top_k=2,
                      n_layers=6, max_seq=512)


def init_weights(cfg: TinyMoEConfig, seed: int = 0):
    """Deterministic scaled-gaussian init; returns {name: np.ndarray f32}."""
    rng = np.random.default_rng(seed)
    out = {}
    for name, shape in cfg.param_shapes().items():
        if name.endswith(("ln1", "ln2", "ln_f")):
            out[name] = np.ones(shape, np.float32)
        else:
            fan_in = shape[-2] if len(shape) >= 2 else shape[0]
            out[name] = rng.normal(
                0.0, 1.0 / np.sqrt(fan_in), size=shape).astype(np.float32)
    return out


def params_list(cfg, weights):
    return [jnp.asarray(weights[n]) for n in cfg.param_names()]


def params_dict(cfg, plist):
    return dict(zip(cfg.param_names(), plist))


# ---------------------------------------------------------------------------
# building blocks
# ---------------------------------------------------------------------------

def rms_norm(x, w, eps=1e-6):
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * w


def rope(x, positions):
    """Rotary embedding. x: [..., s, nh, hd]; positions: [s] or [..., s]."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = 1.0 / (10000.0 ** (jnp.arange(half, dtype=jnp.float32) / half))
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # [..., s, half]
    cos = jnp.cos(angles)[..., :, None, :]   # [..., s, 1, half]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)


def causal_attention(x, wq, wk, wv, wo, cfg, positions=None):
    """Full-prefix causal MHA (prefill path). x: [b, s, h] -> [b, s, h].

    Also returns (k, v) for KV-cache initialization: [b, s, nh, hd].
    """
    b, s, _ = x.shape
    nh, hd = cfg.n_heads, cfg.head_dim
    if positions is None:
        positions = jnp.arange(s)
    q = (x @ wq).reshape(b, s, nh, hd)
    k = (x @ wk).reshape(b, s, nh, hd)
    v = (x @ wv).reshape(b, s, nh, hd)
    q = rope(q, positions)
    k = rope(k, positions)
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    logits = jnp.einsum("bqnd,bknd->bnqk", q, k) * scale
    mask = jnp.tril(jnp.ones((s, s), bool))
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bnqk,bknd->bqnd", probs, v).reshape(b, s, nh * hd)
    return o @ wo, k, v


def dispatch(x, gate_i, n_experts, capacity):
    """Scatter tokens into capacity-packed per-expert buffers.

    x: [t, h]; gate_i: [t, k] -> (buf [E, C, h], flat_e [t*k], slot [t*k],
    tok [t*k], valid [t*k]).  Tokens beyond an expert's capacity are
    dropped (with C >= t the packing is dropless).
    """
    t, h = x.shape
    k = gate_i.shape[1]
    flat_e = gate_i.reshape(-1)                              # [tk]
    onehot = jax.nn.one_hot(flat_e, n_experts, dtype=jnp.int32)
    pos_in_e = jnp.cumsum(onehot, axis=0) - onehot           # [tk, E]
    slot = jnp.sum(pos_in_e * onehot, axis=1)                # [tk]
    tok = jnp.repeat(jnp.arange(t), k)                       # [tk]
    valid = slot < capacity
    buf = jnp.zeros((n_experts, capacity, h), x.dtype)
    buf = buf.at[flat_e, jnp.where(valid, slot, capacity)].set(
        x[tok], mode="drop")
    return buf, flat_e, slot, tok, valid


def combine(buf_out, gate_w, flat_e, slot, tok, valid, t):
    """Gather expert outputs back to token order, weighted by the gate."""
    h = buf_out.shape[-1]
    gathered = buf_out[flat_e, jnp.where(valid, slot, 0)]     # [tk, h]
    w = jnp.where(valid, gate_w.reshape(-1), 0.0)[:, None]
    y = jnp.zeros((t, h), buf_out.dtype)
    return y.at[tok].add(w * gathered)


def moe_block(x, router, wg, wu, wd, sg, su, sd, cfg, block_t=None):
    """Full MoE block on the Pallas path: gate -> dispatch -> grouped
    expert MLP kernel -> combine (+ shared expert).  x: [t, h]."""
    t = x.shape[0]
    bt = block_t or min(64, t)
    gate_w, gate_i = topk_gate(x, router, cfg.top_k, block_t=min(128, t))
    capacity = ((t + bt - 1) // bt) * bt                     # dropless
    buf, flat_e, slot, tok, valid = dispatch(x, gate_i, cfg.n_experts,
                                             capacity)
    buf_out = grouped_expert_mlp(buf, wg, wu, wd, block_t=bt)
    y = combine(buf_out, gate_w, flat_e, slot, tok, valid, t)
    if cfg.shared_expert:
        y = y + ref.expert_mlp_ref(x, sg, su, sd)
    return y


def moe_block_dense_ref(x, router, wg, wu, wd, sg, su, sd, cfg):
    """Dense oracle of moe_block (no dispatch)."""
    return ref.moe_block_ref(
        x, router, wg, wu, wd, cfg.top_k,
        *( (sg, su, sd) if cfg.shared_expert else (None, None, None) ))


# ---------------------------------------------------------------------------
# full model forward passes (AOT entry points)
# ---------------------------------------------------------------------------

def _layer_params(p, i):
    return {n: p[f"l{i}.{n}"] for n in
            ["ln1", "wq", "wk", "wv", "wo", "ln2", "router",
             "wg", "wu", "wd", "sg", "su", "sd"]}


def prefill_fwd(cfg: TinyMoEConfig, tokens, *plist):
    """Prefill: tokens [b, s] i32 -> (logits [b, vocab] at last position,
    k_cache, v_cache [b, smax, L, nh, hd] zero-padded past s)."""
    p = params_dict(cfg, list(plist))
    b, s = tokens.shape
    x = p["embed"][tokens]                                   # [b, s, h]
    kc, vc = [], []
    for i in range(cfg.n_layers):
        lp = _layer_params(p, i)
        a, k, v = causal_attention(rms_norm(x, lp["ln1"]), lp["wq"],
                                   lp["wk"], lp["wv"], lp["wo"], cfg)
        x = x + a
        xr = rms_norm(x, lp["ln2"]).reshape(b * s, cfg.hidden)
        y = moe_block(xr, lp["router"], lp["wg"], lp["wu"], lp["wd"],
                      lp["sg"], lp["su"], lp["sd"], cfg)
        x = x + y.reshape(b, s, cfg.hidden)
        kc.append(k)
        vc.append(v)
    x = rms_norm(x, p["ln_f"])
    logits = x[:, -1] @ p["embed"].T                         # [b, vocab]
    pad = cfg.max_seq - s
    k_cache = jnp.pad(jnp.stack(kc, 2), ((0, 0), (0, pad), (0, 0), (0, 0),
                                         (0, 0)))
    v_cache = jnp.pad(jnp.stack(vc, 2), ((0, 0), (0, pad), (0, 0), (0, 0),
                                         (0, 0)))
    return logits, k_cache, v_cache


def decode_fwd(cfg: TinyMoEConfig, tokens, pos, k_cache, v_cache, *plist):
    """One decode step with KV cache (the serving hot path).

    tokens: [b] i32 (last generated token); pos: scalar i32 (current
    sequence length, i.e. index where this token's K/V are written);
    k_cache/v_cache: [b, smax, L, nh, hd] -> (logits [b, vocab],
    updated caches).  Attention runs the masked Pallas decode kernel.
    """
    p = params_dict(cfg, list(plist))
    b = tokens.shape[0]
    nh, hd = cfg.n_heads, cfg.head_dim
    x = p["embed"][tokens]                                   # [b, h]
    positions = jnp.full((b, 1), pos)
    for i in range(cfg.n_layers):
        lp = _layer_params(p, i)
        xn = rms_norm(x, lp["ln1"])
        q = (xn @ lp["wq"]).reshape(b, 1, nh, hd)
        k = (xn @ lp["wk"]).reshape(b, 1, nh, hd)
        v = (xn @ lp["wv"]).reshape(b, 1, nh, hd)
        q = rope(q, positions)
        k = rope(k, positions)
        k_cache = jax.lax.dynamic_update_slice(
            k_cache, k[:, :, None], (0, pos, i, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(
            v_cache, v[:, :, None], (0, pos, i, 0, 0))
        o = decode_attention_masked(q[:, 0], k_cache[:, :, i],
                                    v_cache[:, :, i], pos + 1)
        x = x + o.reshape(b, nh * hd) @ lp["wo"]
        xr = rms_norm(x, lp["ln2"])
        y = moe_block(xr, lp["router"], lp["wg"], lp["wu"], lp["wd"],
                      lp["sg"], lp["su"], lp["sd"], cfg,
                      block_t=min(8, b))
        x = x + y
    x = rms_norm(x, p["ln_f"])
    return x @ p["embed"].T, k_cache, v_cache


def prefill_fwd_ref(cfg, tokens, *plist):
    """jnp-only oracle of prefill_fwd (dense MoE, plain attention)."""
    p = params_dict(cfg, list(plist))
    b, s = tokens.shape
    x = p["embed"][tokens]
    for i in range(cfg.n_layers):
        lp = _layer_params(p, i)
        a, _, _ = causal_attention(rms_norm(x, lp["ln1"]), lp["wq"],
                                   lp["wk"], lp["wv"], lp["wo"], cfg)
        x = x + a
        xr = rms_norm(x, lp["ln2"]).reshape(b * s, cfg.hidden)
        y = moe_block_dense_ref(xr, lp["router"], lp["wg"], lp["wu"],
                                lp["wd"], lp["sg"], lp["su"], lp["sd"], cfg)
        x = x + y.reshape(b, s, cfg.hidden)
    x = rms_norm(x, p["ln_f"])
    return x[:, -1] @ p["embed"].T


# ---------------------------------------------------------------------------
# shard variants (hybrid TP-EP verification path)
# ---------------------------------------------------------------------------

def attn_tp_shard_fwd(x, wq_s, wk_s, wv_s, wo_s, n_heads_shard, head_dim):
    """TP shard of causal attention: head-parallel column slices of
    Wq/Wk/Wv and row slice of Wo.  Summing the outputs of all shards (the
    AR the paper's TP group performs) equals the full attention output.

    x: [b, s, h]; wq_s/wk_s/wv_s: [h, nh_s*hd]; wo_s: [nh_s*hd, h].
    """
    b, s, _ = x.shape
    nh, hd = n_heads_shard, head_dim
    positions = jnp.arange(s)
    q = rope((x @ wq_s).reshape(b, s, nh, hd), positions)
    k = rope((x @ wk_s).reshape(b, s, nh, hd), positions)
    v = (x @ wv_s).reshape(b, s, nh, hd)
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    logits = jnp.einsum("bqnd,bknd->bnqk", q, k) * scale
    mask = jnp.tril(jnp.ones((s, s), bool))
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bnqk,bknd->bqnd", probs, v).reshape(b, s, nh * hd)
    return o @ wo_s          # partial sum: AR across the TP group completes it


def expert_tp_shard_fwd(x, wg_s, wu_s, wd_s):
    """TP shard of one expert MLP: column slices of Wg/Wu (f dim), row
    slice of Wd.  Sum over shards (intra-node RS in Alg. 1) = full MLP."""
    return ref.expert_mlp_ref(x, wg_s, wu_s, wd_s)


def shard_attention_weights(weights, layer, tp, cfg):
    """Slice layer weights into `tp` head-parallel attention shards."""
    per = cfg.qkv_dim // tp
    out = []
    for r in range(tp):
        sl = slice(r * per, (r + 1) * per)
        out.append(dict(
            wq=weights[f"l{layer}.wq"][:, sl],
            wk=weights[f"l{layer}.wk"][:, sl],
            wv=weights[f"l{layer}.wv"][:, sl],
            wo=weights[f"l{layer}.wo"][sl, :],
        ))
    return out


def shard_expert_weights(weights, layer, expert, tp, cfg):
    """Slice one expert's MLP into `tp` intermediate-dim shards."""
    per = cfg.expert_inter // tp
    out = []
    for r in range(tp):
        sl = slice(r * per, (r + 1) * per)
        out.append(dict(
            wg=weights[f"l{layer}.wg"][expert][:, sl],
            wu=weights[f"l{layer}.wu"][expert][:, sl],
            wd=weights[f"l{layer}.wd"][expert][sl, :],
        ))
    return out
