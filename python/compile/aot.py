"""AOT lowering: JAX (L2, calling L1 Pallas kernels) -> HLO text artifacts.

Interchange format is HLO *text*, not serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (the
version behind the published `xla` 0.1.6 crate) rejects; the text parser
reassigns ids and round-trips cleanly.  See /opt/xla-example/README.md.

Outputs under --out (default ../artifacts):
  *.hlo.txt                 one compiled-once executable per model variant
  manifest.json             artifact -> input/output shapes; model config;
                            ordered parameter names
  weights/<cfg>/<name>.bin  little-endian f32 parameter dumps
  weights/<cfg>/manifest.json

Python runs ONCE at `make artifacts`; Rust never imports it.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _shaped(name, s):
    return {"name": name, "shape": list(s.shape), "dtype": str(s.dtype)}


class Emitter:
    def __init__(self, out_dir):
        self.out_dir = out_dir
        self.manifest = {}
        os.makedirs(out_dir, exist_ok=True)

    def emit(self, name, fn, in_specs, in_names=None):
        """Lower fn at in_specs, write HLO text, record manifest entry."""
        lowered = jax.jit(fn).lower(*in_specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(self.out_dir, fname), "w") as f:
            f.write(text)
        outs = jax.eval_shape(fn, *in_specs)
        if not isinstance(outs, (tuple, list)):
            outs = (outs,)
        in_names = in_names or [f"arg{i}" for i in range(len(in_specs))]
        self.manifest[name] = {
            "file": fname,
            "inputs": [_shaped(n, s) for n, s in zip(in_names, in_specs)],
            "outputs": [_shaped(f"out{i}", s) for i, s in enumerate(outs)],
        }
        print(f"  {name}: {len(text)} chars, "
              f"{len(in_specs)} inputs -> {len(outs)} outputs")


def export_weights(cfg, cfg_name, out_dir, seed=0):
    wdir = os.path.join(out_dir, "weights", cfg_name)
    os.makedirs(wdir, exist_ok=True)
    weights = M.init_weights(cfg, seed)
    man = {}
    for name in cfg.param_names():
        arr = weights[name]
        fname = name.replace(".", "_") + ".bin"
        arr.astype("<f4").tofile(os.path.join(wdir, fname))
        man[name] = {"file": fname, "shape": list(arr.shape)}
    with open(os.path.join(wdir, "manifest.json"), "w") as f:
        json.dump({"params": man, "order": cfg.param_names(),
                   "seed": seed}, f, indent=1)
    return weights


# Shape buckets compiled for the serving path: the Rust engine pads a
# batch to the nearest bucket (vLLM-style multi-executable serving).
PREFILL_BUCKETS = [(1, 16), (1, 32), (1, 64), (2, 32), (4, 16), (4, 32),
                   (8, 16), (8, 32)]
DECODE_BATCHES = [1, 2, 4, 8]


def build(cfg: M.TinyMoEConfig, cfg_name: str, out_dir: str):
    em = Emitter(out_dir)
    weights = export_weights(cfg, cfg_name, out_dir)
    del weights

    pshapes = [cfg.param_shapes()[n] for n in cfg.param_names()]
    pspecs = [spec(s) for s in pshapes]
    pnames = cfg.param_names()
    c = cfg
    cache_shape = (0, c.max_seq, c.n_layers, c.n_heads, c.head_dim)

    print(f"[aot] building '{cfg_name}' "
          f"({cfg.n_params()/1e6:.1f}M params) -> {out_dir}")

    # --- serving-path executables -------------------------------------
    for b, s in PREFILL_BUCKETS:
        if s > cfg.max_seq:
            continue
        em.emit(
            f"{cfg_name}_prefill_b{b}_s{s}",
            lambda toks, *p: M.prefill_fwd(c, toks, *p),
            [spec((b, s), jnp.int32)] + pspecs,
            ["tokens"] + pnames)

    for b in DECODE_BATCHES:
        kv = spec((b,) + cache_shape[1:])
        em.emit(
            f"{cfg_name}_decode_b{b}",
            lambda toks, pos, kc, vc, *p: M.decode_fwd(
                c, toks, pos[0], kc, vc, *p),
            [spec((b,), jnp.int32), spec((1,), jnp.int32), kv, kv] + pspecs,
            ["tokens", "pos", "k_cache", "v_cache"] + pnames)

    # --- hybrid TP-EP verification shards (weights are runtime inputs,
    # so one artifact serves every rank) --------------------------------
    vb, vs = 2, 16
    em.emit(
        f"{cfg_name}_attn_full_b{vb}_s{vs}",
        lambda x, wq, wk, wv, wo: M.causal_attention(x, wq, wk, wv, wo, c)[0],
        [spec((vb, vs, c.hidden))] + [
            spec((c.hidden, c.qkv_dim))] * 3 + [spec((c.qkv_dim, c.hidden))],
        ["x", "wq", "wk", "wv", "wo"])

    for m in (2, 4):
        nh_s = c.n_heads // m
        if nh_s == 0:
            continue
        d_s = nh_s * c.head_dim
        em.emit(
            f"{cfg_name}_attn_shard_tp{m}_b{vb}_s{vs}",
            lambda x, wq, wk, wv, wo, _nh=nh_s: M.attn_tp_shard_fwd(
                x, wq, wk, wv, wo, _nh, c.head_dim),
            [spec((vb, vs, c.hidden))] + [spec((c.hidden, d_s))] * 3 +
            [spec((d_s, c.hidden))],
            ["x", "wq_s", "wk_s", "wv_s", "wo_s"])

    t = 32
    em.emit(
        f"{cfg_name}_expert_mlp_t{t}",
        lambda x, wg, wu, wd: M.expert_tp_shard_fwd(x, wg, wu, wd),
        [spec((t, c.hidden)), spec((c.hidden, c.expert_inter)),
         spec((c.hidden, c.expert_inter)), spec((c.expert_inter, c.hidden))],
        ["x", "wg", "wu", "wd"])
    em.emit(
        f"{cfg_name}_expert_mlp_tp2_t{t}",
        lambda x, wg, wu, wd: M.expert_tp_shard_fwd(x, wg, wu, wd),
        [spec((t, c.hidden)), spec((c.hidden, c.expert_inter // 2)),
         spec((c.hidden, c.expert_inter // 2)),
         spec((c.expert_inter // 2, c.hidden))],
        ["x", "wg_s", "wu_s", "wd_s"])

    tg = 64
    em.emit(
        f"{cfg_name}_gate_t{tg}",
        lambda x, r: M.topk_gate(x, r, c.top_k, block_t=min(128, tg)),
        [spec((tg, c.hidden)), spec((c.hidden, c.n_experts))],
        ["x", "router"])

    em.emit(
        f"{cfg_name}_moe_block_dense_t{tg}",
        lambda x, r, wg, wu, wd, sg, su, sd: M.moe_block_dense_ref(
            x, r, wg, wu, wd, sg, su, sd, c),
        [spec((tg, c.hidden)), spec((c.hidden, c.n_experts)),
         spec((c.n_experts, c.hidden, c.expert_inter)),
         spec((c.n_experts, c.hidden, c.expert_inter)),
         spec((c.n_experts, c.expert_inter, c.hidden)),
         spec((c.hidden, c.expert_inter)), spec((c.hidden, c.expert_inter)),
         spec((c.expert_inter, c.hidden))],
        ["x", "router", "wg", "wu", "wd", "sg", "su", "sd"])

    return em.manifest


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--configs", default="tiny",
                    help="comma list: tiny,small")
    args = ap.parse_args()
    out = os.path.abspath(args.out)
    manifest = {"artifacts": {}, "models": {}}
    for name in args.configs.split(","):
        cfg = {"tiny": M.TINY, "small": M.SMALL}[name]
        manifest["artifacts"].update(build(cfg, name, out))
        manifest["models"][name] = {
            **{k: getattr(cfg, k) for k in
               ["vocab", "hidden", "n_heads", "head_dim", "expert_inter",
                "n_experts", "top_k", "shared_expert", "n_layers",
                "max_seq"]},
            "n_params": cfg.n_params(),
            "param_order": cfg.param_names(),
            "prefill_buckets": [[b, s] for b, s in PREFILL_BUCKETS
                                if s <= cfg.max_seq],
            "decode_batches": DECODE_BATCHES,
        }
    with open(os.path.join(out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] wrote {len(manifest['artifacts'])} artifacts + manifest")


if __name__ == "__main__":
    main()
