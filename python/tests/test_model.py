"""L2 model correctness: Pallas path vs dense jnp oracle, shard algebra,
decode-vs-prefill consistency, dispatch/combine invariants."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import model as M
from compile.kernels import ref

SETTINGS = dict(max_examples=8, deadline=None)

CFG = M.TinyMoEConfig(vocab=64, hidden=32, n_heads=2, head_dim=16,
                      expert_inter=48, n_experts=4, top_k=2, n_layers=2,
                      max_seq=32)


def _weights(seed=0, cfg=CFG):
    w = M.init_weights(cfg, seed)
    return w, M.params_list(cfg, w)


def _tokens(rng, b, s, cfg=CFG):
    return jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32)


# ---------------------------------------------------------------------------
# config plumbing
# ---------------------------------------------------------------------------

def test_param_names_shapes_consistent():
    names = CFG.param_names()
    shapes = CFG.param_shapes()
    assert names == list(shapes.keys())
    assert len(names) == 2 + 13 * CFG.n_layers
    assert CFG.n_params() == sum(int(np.prod(s)) for s in shapes.values())


def test_init_weights_deterministic():
    a = M.init_weights(CFG, 42)
    b = M.init_weights(CFG, 42)
    for n in CFG.param_names():
        np.testing.assert_array_equal(a[n], b[n])


def test_tiny_and_small_presets():
    assert M.TINY.n_params() < M.SMALL.n_params()
    assert M.SMALL.n_params() > 50e6, "SMALL must be a real ~100M-class model"


# ---------------------------------------------------------------------------
# MoE block: pallas dispatch path vs dense oracle
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(t=st.sampled_from([16, 32, 64]), seed=st.integers(0, 2**31 - 1))
def test_moe_block_pallas_vs_dense(t, seed):
    rng = np.random.default_rng(seed)
    w, _ = _weights()
    x = jnp.asarray(rng.normal(0, 1, (t, CFG.hidden)), jnp.float32)
    args = [jnp.asarray(w[f"l0.{n}"]) for n in
            ["router", "wg", "wu", "wd", "sg", "su", "sd"]]
    got = M.moe_block(x, *args, CFG, block_t=16)
    want = M.moe_block_dense_ref(x, *args, CFG)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


@settings(**SETTINGS)
@given(t=st.sampled_from([8, 24, 40]), k=st.integers(1, 3),
       seed=st.integers(0, 2**31 - 1))
def test_dispatch_combine_token_conservation(t, k, seed):
    """dispatch then combine with identity experts and uniform gate == x."""
    rng = np.random.default_rng(seed)
    h, e = 16, 4
    x = jnp.asarray(rng.normal(0, 1, (t, h)), jnp.float32)
    gate_i = jnp.asarray(
        np.stack([rng.choice(e, size=k, replace=False) for _ in range(t)]),
        jnp.int32)
    gate_w = jnp.full((t, k), 1.0 / k, jnp.float32)
    buf, flat_e, slot, tok, valid = M.dispatch(x, gate_i, e, capacity=t)
    assert bool(valid.all()), "capacity=t must be dropless"
    y = M.combine(buf, gate_w, flat_e, slot, tok, valid, t)
    np.testing.assert_allclose(y, x, rtol=1e-5, atol=1e-6)


def test_dispatch_respects_capacity():
    rng = np.random.default_rng(0)
    t, h, e, cap = 16, 8, 2, 4
    x = jnp.asarray(rng.normal(0, 1, (t, h)), jnp.float32)
    gate_i = jnp.zeros((t, 1), jnp.int32)  # all tokens -> expert 0
    buf, _, slot, _, valid = M.dispatch(x, gate_i, e, capacity=cap)
    assert int(valid.sum()) == cap
    assert buf.shape == (e, cap, h)


# ---------------------------------------------------------------------------
# full forward passes
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(b=st.sampled_from([1, 2]), s=st.sampled_from([8, 16]),
       seed=st.integers(0, 2**31 - 1))
def test_prefill_matches_ref(b, s, seed):
    rng = np.random.default_rng(seed)
    _, pl_ = _weights()
    toks = _tokens(rng, b, s)
    logits, kc, vc = M.prefill_fwd(CFG, toks, *pl_)
    want = M.prefill_fwd_ref(CFG, toks, *pl_)
    np.testing.assert_allclose(logits, want, rtol=5e-4, atol=5e-4)
    assert kc.shape == (b, CFG.max_seq, CFG.n_layers, CFG.n_heads,
                        CFG.head_dim)
    # cache is zero-padded past s
    assert float(jnp.abs(kc[:, s:]).max()) == 0.0


def test_decode_consistent_with_prefill():
    """Greedy decode via the KV-cache path == recompute-from-scratch."""
    rng = np.random.default_rng(5)
    _, pl_ = _weights()
    b, s = 2, 8
    toks = _tokens(rng, b, s)
    logits, kc, vc = M.prefill_fwd(CFG, toks, *pl_)
    cur = toks
    for step in range(3):
        nxt = jnp.argmax(logits, -1).astype(jnp.int32)
        logits, kc, vc = M.decode_fwd(CFG, nxt, jnp.int32(s + step), kc, vc,
                                      *pl_)
        cur = jnp.concatenate([cur, nxt[:, None]], 1)
        want, _, _ = M.prefill_fwd(CFG, cur, *pl_)
        np.testing.assert_allclose(logits, want, rtol=5e-3, atol=5e-3)


# ---------------------------------------------------------------------------
# shard algebra (what the fused AR-A2A schedules move over the wire)
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(tp=st.sampled_from([1, 2]), seed=st.integers(0, 2**31 - 1))
def test_attention_tp_shards_sum_to_full(tp, seed):
    rng = np.random.default_rng(seed)
    w, _ = _weights()
    x = jnp.asarray(rng.normal(0, 1, (2, 8, CFG.hidden)), jnp.float32)
    full, _, _ = M.causal_attention(
        x, *[jnp.asarray(w[f"l0.{n}"]) for n in ["wq", "wk", "wv", "wo"]],
        CFG)
    shards = M.shard_attention_weights(w, 0, tp, CFG)
    acc = sum(
        M.attn_tp_shard_fwd(x, jnp.asarray(sh["wq"]), jnp.asarray(sh["wk"]),
                            jnp.asarray(sh["wv"]), jnp.asarray(sh["wo"]),
                            CFG.n_heads // tp, CFG.head_dim)
        for sh in shards)
    np.testing.assert_allclose(acc, full, rtol=1e-4, atol=1e-5)


@settings(**SETTINGS)
@given(tp=st.sampled_from([2, 4]), expert=st.integers(0, 3),
       seed=st.integers(0, 2**31 - 1))
def test_expert_tp_shards_sum_to_full(tp, expert, seed):
    rng = np.random.default_rng(seed)
    w, _ = _weights()
    x = jnp.asarray(rng.normal(0, 1, (16, CFG.hidden)), jnp.float32)
    full = ref.expert_mlp_ref(x, jnp.asarray(w["l0.wg"][expert]),
                              jnp.asarray(w["l0.wu"][expert]),
                              jnp.asarray(w["l0.wd"][expert]))
    shards = M.shard_expert_weights(w, 0, expert, tp, CFG)
    acc = sum(M.expert_tp_shard_fwd(x, jnp.asarray(sh["wg"]),
                                    jnp.asarray(sh["wu"]),
                                    jnp.asarray(sh["wd"])) for sh in shards)
    np.testing.assert_allclose(acc, full, rtol=1e-4, atol=1e-5)


def test_ep_expert_partition_equals_dense():
    """EP: computing each expert on its own 'rank' and combining by the
    gate == the dense MoE block (what fused RS-Combine reproduces)."""
    rng = np.random.default_rng(9)
    w, _ = _weights()
    t = 16
    x = jnp.asarray(rng.normal(0, 1, (t, CFG.hidden)), jnp.float32)
    router = jnp.asarray(w["l0.router"])
    gate_w, gate_i = ref.topk_gate_ref(x, router, CFG.top_k)
    y = jnp.zeros_like(x)
    for e in range(CFG.n_experts):          # each "EP rank" computes its expert
        out_e = ref.expert_mlp_ref(x, jnp.asarray(w["l0.wg"][e]),
                                   jnp.asarray(w["l0.wu"][e]),
                                   jnp.asarray(w["l0.wd"][e]))
        sel = (gate_i == e).any(-1)
        wsel = jnp.where(gate_i == e, gate_w, 0.0).sum(-1)
        y = y + out_e * (wsel * sel)[:, None]
    y = y + ref.expert_mlp_ref(x, jnp.asarray(w["l0.sg"]),
                               jnp.asarray(w["l0.su"]),
                               jnp.asarray(w["l0.sd"]))
    want = M.moe_block_dense_ref(
        x, router, *[jnp.asarray(w[f"l0.{n}"]) for n in
                     ["wg", "wu", "wd", "sg", "su", "sd"]], CFG)
    np.testing.assert_allclose(y, want, rtol=2e-4, atol=2e-4)
