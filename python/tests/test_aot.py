"""AOT pipeline: manifest integrity, weight export round-trip, HLO text
parseability markers."""

import json
import os

import numpy as np
import pytest

from compile import aot, model as M

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def _manifest():
    path = os.path.join(ART, "manifest.json")
    if not os.path.exists(path):
        pytest.skip("artifacts not built (run `make artifacts`)")
    with open(path) as f:
        return json.load(f)


def test_manifest_lists_every_artifact_file():
    man = _manifest()
    for name, ent in man["artifacts"].items():
        path = os.path.join(ART, ent["file"])
        assert os.path.exists(path), f"missing {path}"
        assert ent["inputs"] and ent["outputs"]


def test_hlo_text_is_text_not_proto():
    man = _manifest()
    name, ent = next(iter(man["artifacts"].items()))
    with open(os.path.join(ART, ent["file"])) as f:
        head = f.read(200)
    assert "HloModule" in head, "interchange must be HLO text"


def test_prefill_manifest_shapes():
    man = _manifest()
    tiny = man["models"]["tiny"]
    for b, s in tiny["prefill_buckets"]:
        ent = man["artifacts"][f"tiny_prefill_b{b}_s{s}"]
        assert ent["inputs"][0]["shape"] == [b, s]
        assert ent["inputs"][0]["dtype"] == "int32"
        # logits + 2 caches
        assert len(ent["outputs"]) == 3
        assert ent["outputs"][0]["shape"] == [b, tiny["vocab"]]


def test_decode_manifest_shapes():
    man = _manifest()
    tiny = man["models"]["tiny"]
    for b in tiny["decode_batches"]:
        ent = man["artifacts"][f"tiny_decode_b{b}"]
        cache = [b, tiny["max_seq"], tiny["n_layers"], tiny["n_heads"],
                 tiny["head_dim"]]
        assert ent["inputs"][2]["shape"] == cache
        assert ent["outputs"][1]["shape"] == cache


def test_weight_export_roundtrip(tmp_path):
    cfg = M.TinyMoEConfig(vocab=32, hidden=16, n_heads=2, head_dim=8,
                          expert_inter=24, n_experts=2, top_k=1,
                          n_layers=1, max_seq=16)
    weights = aot.export_weights(cfg, "t", str(tmp_path))
    man = json.load(open(tmp_path / "weights" / "t" / "manifest.json"))
    assert man["order"] == cfg.param_names()
    for name in cfg.param_names():
        ent = man["params"][name]
        arr = np.fromfile(tmp_path / "weights" / "t" / ent["file"],
                          dtype="<f4").reshape(ent["shape"])
        np.testing.assert_array_equal(arr, weights[name])


def test_param_order_matches_model():
    man = _manifest()
    assert man["models"]["tiny"]["param_order"] == M.TINY.param_names()
