"""L1 Pallas kernel correctness: hypothesis sweeps shapes/dtypes vs ref.py."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.attention import (decode_attention,
                                       decode_attention_masked)
from compile.kernels.moe_mlp import (grouped_expert_mlp, expert_mlp,
                                     vmem_bytes_per_step)
from compile.kernels.topk_gate import topk_gate

SETTINGS = dict(max_examples=12, deadline=None)


def randn(rng, shape, scale=0.1, dtype=np.float32):
    return jnp.asarray(rng.normal(0.0, scale, size=shape), dtype)


# ---------------------------------------------------------------------------
# grouped expert MLP
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(
    e=st.sampled_from([1, 2, 4, 8]),
    c_blocks=st.integers(1, 3),
    block_t=st.sampled_from([8, 16, 32]),
    h=st.sampled_from([16, 32, 64]),
    f=st.sampled_from([16, 48, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_grouped_expert_mlp_matches_ref(e, c_blocks, block_t, h, f, seed):
    rng = np.random.default_rng(seed)
    c = c_blocks * block_t
    xs = randn(rng, (e, c, h), 1.0)
    wg = randn(rng, (e, h, f))
    wu = randn(rng, (e, h, f))
    wd = randn(rng, (e, f, h))
    got = grouped_expert_mlp(xs, wg, wu, wd, block_t=block_t)
    want = ref.grouped_expert_mlp_ref(xs, wg, wu, wd)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_expert_mlp_single_expert_wrapper():
    rng = np.random.default_rng(0)
    x = randn(rng, (32, 16), 1.0)
    wg, wu, wd = randn(rng, (16, 24)), randn(rng, (16, 24)), randn(rng, (24, 16))
    got = expert_mlp(x, wg, wu, wd)
    want = ref.expert_mlp_ref(x, wg, wu, wd)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_grouped_expert_mlp_rejects_bad_capacity():
    rng = np.random.default_rng(0)
    xs = randn(rng, (2, 10, 8))
    w = randn(rng, (2, 8, 8))
    wd = randn(rng, (2, 8, 8))
    with pytest.raises(ValueError):
        grouped_expert_mlp(xs, w, w, wd, block_t=4)


def test_vmem_estimate_positive_and_monotone():
    a = vmem_bytes_per_step(32, 128, 256)
    b = vmem_bytes_per_step(64, 128, 256)
    assert 0 < a < b


# ---------------------------------------------------------------------------
# top-k gate
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(
    t_blocks=st.integers(1, 3),
    block_t=st.sampled_from([16, 32]),
    h=st.sampled_from([16, 64]),
    e=st.sampled_from([4, 8, 16]),
    k=st.integers(1, 4),
    seed=st.integers(0, 2**31 - 1),
)
def test_topk_gate_matches_ref(t_blocks, block_t, h, e, k, seed):
    rng = np.random.default_rng(seed)
    t = t_blocks * block_t
    x = randn(rng, (t, h), 1.0)
    wr = randn(rng, (h, e), 1.0)
    got_w, got_i = topk_gate(x, wr, k, block_t=block_t)
    want_w, want_i = ref.topk_gate_ref(x, wr, k)
    np.testing.assert_array_equal(np.asarray(got_i), np.asarray(want_i))
    np.testing.assert_allclose(got_w, want_w, rtol=1e-4, atol=1e-6)


def test_topk_gate_weights_normalized_and_sorted():
    rng = np.random.default_rng(3)
    x = randn(rng, (32, 16), 1.0)
    wr = randn(rng, (16, 8), 1.0)
    w, i = topk_gate(x, wr, 3, block_t=32)
    w = np.asarray(w)
    np.testing.assert_allclose(w.sum(-1), 1.0, rtol=1e-5)
    assert (np.diff(w, axis=-1) <= 1e-7).all(), "top-k must be descending"
    assert (np.asarray(i) >= 0).all() and (np.asarray(i) < 8).all()


def test_topk_gate_indices_distinct():
    rng = np.random.default_rng(4)
    x = randn(rng, (64, 32), 1.0)
    wr = randn(rng, (32, 8), 1.0)
    _, i = topk_gate(x, wr, 4, block_t=64)
    i = np.asarray(i)
    for row in i:
        assert len(set(row.tolist())) == 4


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(
    b=st.sampled_from([1, 2, 4]),
    nh=st.sampled_from([1, 2, 4]),
    hd=st.sampled_from([8, 16, 32]),
    s=st.sampled_from([16, 64, 96, 100]),
    chunk=st.sampled_from([16, 32, 128]),
    seed=st.integers(0, 2**31 - 1),
)
def test_decode_attention_matches_ref(b, nh, hd, s, chunk, seed):
    rng = np.random.default_rng(seed)
    q = randn(rng, (b, nh, hd), 1.0)
    k = randn(rng, (b, s, nh, hd), 1.0)
    v = randn(rng, (b, s, nh, hd), 1.0)
    got = decode_attention(q, k, v, chunk_s=chunk)
    want = ref.decode_attention_ref(q, k, v)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


@settings(**SETTINGS)
@given(
    valid=st.integers(1, 48),
    seed=st.integers(0, 2**31 - 1),
)
def test_masked_decode_attention_ignores_padding(valid, seed):
    """Masked kernel over a padded cache == plain ref over the valid prefix,
    regardless of garbage in the padded region."""
    rng = np.random.default_rng(seed)
    b, nh, hd, smax = 2, 2, 16, 48
    q = randn(rng, (b, nh, hd), 1.0)
    k = randn(rng, (b, smax, nh, hd), 1.0)
    v = randn(rng, (b, smax, nh, hd), 1.0)
    # poison the padding
    k = k.at[:, valid:].set(1e9)
    v = v.at[:, valid:].set(-1e9)
    got = decode_attention_masked(q, k, v, valid, chunk_s=16)
    want = ref.decode_attention_ref(q, k[:, :valid], v[:, :valid])
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_decode_attention_softmax_rowsum():
    """Output must be a convex combination of V rows: bounded by min/max."""
    rng = np.random.default_rng(7)
    b, nh, hd, s = 2, 2, 8, 32
    q = randn(rng, (b, nh, hd), 1.0)
    k = randn(rng, (b, s, nh, hd), 1.0)
    v = jnp.ones((b, s, nh, hd), jnp.float32) * 3.0
    got = np.asarray(decode_attention(q, k, v))
    np.testing.assert_allclose(got, 3.0, rtol=1e-5)
