//! End-to-end serving driver (the DESIGN.md-mandated validation run):
//! load the real AOT tiny-MoE model through PJRT, serve a Poisson trace
//! of batched requests with continuous batching + paged KV admission,
//! and report measured TTFT / ITL / throughput.
//!
//! This proves all three layers compose: L1 Pallas kernels (grouped
//! expert MLP, top-k gate, masked decode attention) → L2 JAX model → HLO
//! text artifacts → L3 Rust scheduler + PJRT runtime.  Python is not on
//! the path (run `make artifacts` once beforehand).
//!
//! Run: `make artifacts && cargo run --release --example serve_e2e`
//! Flags: --rate R (req/s), --duration S, --artifacts DIR, --model tiny

use mixserve::runtime::Engine;
use mixserve::serving::engine::RealEngine;
use mixserve::util::cli::Args;
use mixserve::workload::TraceGen;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let root = args.get_or("artifacts", "artifacts");
    let model = args.get_or("model", "tiny");
    let rate = args.f64_or("rate", 1.0);
    let duration = args.f64_or("duration", 5.0);

    let engine = Engine::new(&root)?;
    println!(
        "PJRT platform: {} | artifacts: {} entries",
        engine.platform(),
        engine.store.artifacts.len()
    );
    let mut server = RealEngine::new(&engine, &model)?;
    println!(
        "model '{}': vocab {}, max_seq {}, decode batch ≤ {}",
        model,
        server.runner.vocab,
        server.runner.max_seq,
        server.runner.max_decode_batch()
    );

    let mut gen = TraceGen::sharegpt(rate, server.runner.max_seq, 11);
    let trace = gen.generate(duration);
    println!(
        "serving {} requests over {duration}s at {rate} req/s ...",
        trace.len()
    );
    let metrics = server.serve(&trace, 42)?;
    println!("\n=== end-to-end results (real PJRT execution) ===");
    println!("{}", metrics.report("serve_e2e"));
    let t = metrics.ttft_summary();
    let i = metrics.itl_summary();
    println!(
        "completed {} requests | TTFT p50 {:.1}ms | ITL p50 {:.2}ms | executables compiled: {}",
        metrics.completed,
        t.p50 * 1e3,
        i.p50 * 1e3,
        engine.compiled_count()
    );
    anyhow::ensure!(metrics.completed > 0, "no requests completed");
    println!("serve_e2e OK");
    Ok(())
}
