//! Autotune: watch the automatic analyzer adapt the parallel strategy as
//! the cluster changes (§IV-C1: "when cluster bandwidth or node count
//! changes, MixServe re-evaluates the cost model and picks the best
//! feasible tuple").
//!
//! Sweeps inter-node bandwidth and node count for DeepSeek-R1 and prints
//! the winning strategy per point.
//!
//! Run: `cargo run --release --example autotune`

use mixserve::analyzer::indicators::Workload;
use mixserve::analyzer::search::{Analyzer, Objective};
use mixserve::config::{ClusterConfig, MoEModelConfig, ServingConfig};

fn main() {
    let model = MoEModelConfig::deepseek_r1();
    let serving = ServingConfig::paper_eval(4.0);
    let wl = Workload::sharegpt(4.0);

    println!("=== sweep 1: inter-node bandwidth (4×8 Ascend-class cluster) ===");
    println!(
        "{:>12} {:<36} {:>10} {:>10}",
        "inter BW", "winning strategy", "TTFT(ms)", "tok/s"
    );
    for gbps in [25.0, 50.0, 100.0, 200.0, 400.0, 900.0] {
        let mut cluster = ClusterConfig::ascend910b();
        cluster.inter_bw = gbps / 8.0 * 1e9;
        let analyzer = Analyzer::new(&model, &cluster, &serving);
        if let Some(best) = analyzer.best(&wl, Objective::MaxThroughput) {
            println!(
                "{:>9} Gb {:<36} {:>10.1} {:>10.1}",
                gbps,
                best.strategy.to_string(),
                best.indicators.ttft * 1e3,
                best.indicators.throughput
            );
        }
    }

    println!("\n=== sweep 2: node count (8 devices per node) ===");
    println!(
        "{:>12} {:<36} {:>10} {:>10}",
        "nodes", "winning strategy", "TTFT(ms)", "tok/s"
    );
    for nodes in [2usize, 4, 8] {
        let mut cluster = ClusterConfig::ascend910b();
        cluster.n_nodes = nodes;
        cluster.name = format!("Ascend-{nodes}x8");
        let analyzer = Analyzer::new(&model, &cluster, &serving);
        if let Some(best) = analyzer.best(&wl, Objective::MaxThroughput) {
            println!(
                "{:>12} {:<36} {:>10.1} {:>10.1}",
                nodes,
                best.strategy.to_string(),
                best.indicators.ttft * 1e3,
                best.indicators.throughput
            );
        }
    }

    println!("\n=== sweep 3: objective matters ===");
    let cluster = ClusterConfig::h20();
    let analyzer = Analyzer::new(&model, &cluster, &serving);
    for (name, obj) in [
        ("min TTFT", Objective::MinTtft),
        ("min ITL", Objective::MinItl),
        ("max throughput", Objective::MaxThroughput),
    ] {
        if let Some(best) = analyzer.best(&wl, obj) {
            println!("  {name:<16} -> {}", best.strategy);
        }
    }
}
