//! Gantt demo: render the paper's schedule comparisons as ASCII charts —
//! Fig. 4 (pure EP vs hybrid TP+EP for one MoE block) and Fig. 12a
//! (fused RS-Combine sync vs async), with real data moving through the
//! fused algorithms so the run re-verifies numerics as it draws.
//!
//! Run: `cargo run --release --example gantt_demo`

use mixserve::comm::cost::CollectiveCost;
use mixserve::comm::fused::{fused_ag_dispatch, fused_rs_combine, rs_combine_reference,
                            dispatch_reference, Route};
use mixserve::comm::primitives::synth_contrib;
use mixserve::comm::world::{RankWorld, Tensor2};
use mixserve::config::ClusterConfig;
use mixserve::paperbench::{fig12, fig4};

fn main() {
    let cluster = ClusterConfig::ascend910b();
    println!("{}", fig4::run(&cluster));

    // fused RS-Combine with live verification
    let world = RankWorld::new(4, 4);
    let cost = CollectiveCost::new(&cluster);
    let contrib = synth_contrib(&world, 32, 64, 99);
    let res = fused_rs_combine(&world, &contrib, &cost);
    let want = rs_combine_reference(&world, &contrib);
    let max_err = res
        .per_node
        .iter()
        .zip(&want)
        .map(|(g, w)| g.max_abs_diff(w))
        .fold(0.0f32, f32::max);
    println!(
        "fused RS-Combine (Alg. 1): async {:.3}ms sync {:.3}ms speedup {:.2}x | max |err| vs dense = {:.2e}",
        res.async_time() * 1e3,
        res.sync_time * 1e3,
        res.speedup(),
        max_err
    );

    // fused AG-Dispatch with live verification
    let tokens: Vec<Tensor2> = (0..4)
        .map(|s| Tensor2::from_fn(24, 64, |r, c| (s * 31 + r * 7 + c) as f32 * 0.01))
        .collect();
    let route: Route = (0..4).map(|s| (0..24).map(|t| (s + t) % 4).collect()).collect();
    let res2 = fused_ag_dispatch(&world, &tokens, &route, &cost);
    let want2 = dispatch_reference(&tokens, &route);
    let exact = res2.per_node.iter().zip(&want2).all(|(g, w)| g == w);
    println!(
        "fused AG-Dispatch (Alg. 2): async {:.3}ms sync {:.3}ms speedup {:.2}x | exact match: {exact}",
        res2.async_time() * 1e3,
        res2.sync_time * 1e3,
        res2.speedup()
    );

    println!("\n{}", fig12::gantt(&cluster));
    assert!(max_err < 1e-3 && exact, "fused algorithms must verify");
    println!("gantt_demo OK");
}
