//! Quickstart: the MixServe offline stage in ~30 lines.
//!
//! Feed the analyzer a model + cluster description and get back the
//! optimal parallel strategy with predicted TTFT / ITL / throughput —
//! §III-A's offline stage, no GPUs required.
//!
//! Run: `cargo run --release --example quickstart`

use mixserve::analyzer::indicators::Workload;
use mixserve::analyzer::search::{Analyzer, Objective};
use mixserve::config::{ClusterConfig, MoEModelConfig, ServingConfig};

fn main() {
    let model = MoEModelConfig::deepseek_r1();
    let cluster = ClusterConfig::ascend910b();
    let serving = ServingConfig::paper_eval(4.0);
    let workload = Workload::sharegpt(4.0);

    println!(
        "MixServe quickstart — {} ({:.0}B params, {:.0}B active) on {}",
        model.name,
        model.total_params() as f64 / 1e9,
        model.active_params() as f64 / 1e9,
        cluster.name
    );

    let analyzer = Analyzer::new(&model, &cluster, &serving);
    let ranked = analyzer.rank(&workload, Objective::MaxThroughput);
    println!("\ntop 5 of {} feasible strategies:", ranked.len());
    for r in ranked.iter().take(5) {
        println!(
            "  {:<36} TTFT {:>7.1}ms  ITL {:>6.2}ms  {:>7.1} tok/s",
            r.strategy.to_string(),
            r.indicators.ttft * 1e3,
            r.indicators.itl * 1e3,
            r.indicators.throughput
        );
    }
    let best = ranked.first().expect("a feasible strategy");
    println!("\noptimal: {}", best.strategy);
    println!(
        "memory per device: {:.1} GB of {:.1} GB usable",
        best.memory.total() as f64 / 1e9,
        best.memory.limit_bytes as f64 / 1e9
    );
}
